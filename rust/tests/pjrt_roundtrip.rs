//! Integration proof of the three-layer composition: the TLR Cholesky /
//! LDLᵀ running with `Backend::Pjrt` — every ARA sampling chain executed
//! by the AOT-compiled JAX/Pallas artifacts through the PJRT C API —
//! must agree with the native rust gemm backend.
//!
//! Requires `make artifacts` (skipped with a message otherwise, so
//! `cargo test` works on a fresh checkout before the python step).

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::apps::matgen::MatGen;
use h2opus_tlr::ara::sampler::Sampler;
use h2opus_tlr::factor::{cholesky, cholesky_with, ldlt, ldlt_with, FactorOpts};
use h2opus_tlr::linalg::gemm::{matmul, matmul_nt, matmul_tn};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::runtime::{default_artifacts_dir, Backend, PjrtEngine, TermRef};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use h2opus_tlr::tlr::matrix::TlrMatrix;
use h2opus_tlr::Matrix;

fn engine() -> Option<PjrtEngine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    Some(PjrtEngine::new(dir).expect("engine construction"))
}

fn covariance_tlr(n: usize, m: usize, eps: f64, seed: u64) -> (TlrMatrix, Matrix) {
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, m);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let dense = cov.dense();
    let tlr = build_tlr(&cov, &c.offsets, &BuildOpts { eps, method: Compression::Svd, seed });
    (tlr, dense)
}

#[test]
fn engine_sample_update_matches_native_chain() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(11);
    // Mixed shapes within one batch: ranks 3/9/16, tile sizes 64/48.
    let cases = [(64usize, 3usize), (64, 9), (48, 16), (64, 16), (32, 5)];
    let mats: Vec<[Matrix; 4]> = cases
        .iter()
        .map(|&(m, k)| {
            [
                rng.normal_matrix(m, k),
                rng.normal_matrix(m, k),
                rng.normal_matrix(m, k),
                rng.normal_matrix(m, k),
            ]
        })
        .collect();
    let omegas: Vec<Matrix> = cases.iter().map(|&(m, _)| rng.normal_matrix(m, 8)).collect();
    let terms: Vec<TermRef> = mats
        .iter()
        .map(|[uk, vk, ui, vi]| TermRef { uk, vk, ui, vi, d: None })
        .collect();
    let omega_refs: Vec<&Matrix> = omegas.iter().collect();
    let got = e.sample_update(&terms, &omega_refs).unwrap();
    for (idx, ([uk, vk, ui, vi], om)) in mats.iter().zip(&omegas).enumerate() {
        // ui (viᵀ (vk (ukᵀ Ω)))
        let expect = matmul(ui, &matmul_tn(vi, &matmul(vk, &matmul_tn(uk, om))));
        let d = got[idx].sub(&expect).norm_max();
        assert!(d < 1e-10, "case {idx}: pjrt vs native diff {d}");
    }
    assert!(e.stats().launches > 0);
}

#[test]
fn engine_ldl_chain_matches_native() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(12);
    let (m, k, bs) = (64usize, 10usize, 8usize);
    let uk = rng.normal_matrix(m, k);
    let vk = rng.normal_matrix(m, k);
    let ui = rng.normal_matrix(m, k);
    let vi = rng.normal_matrix(m, k);
    let d: Vec<f64> = (0..m).map(|i| 0.5 + i as f64 / 7.0).collect();
    let om = rng.normal_matrix(m, bs);
    let got = e
        .sample_update(&[TermRef { uk: &uk, vk: &vk, ui: &ui, vi: &vi, d: Some(&d) }], &[&om])
        .unwrap();
    // ui (viᵀ (D (vk (ukᵀ Ω))))
    let mut t2 = matmul(&vk, &matmul_tn(&uk, &om));
    for r in 0..m {
        for c in 0..bs {
            t2[(r, c)] *= d[r];
        }
    }
    let expect = matmul(&ui, &matmul_tn(&vi, &t2));
    assert!(got[0].sub(&expect).norm_max() < 1e-10);
}

#[test]
fn engine_tile_apply_matches_native() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(13);
    let u = rng.normal_matrix(40, 7);
    let v = rng.normal_matrix(64, 7);
    let om = rng.normal_matrix(64, 6);
    let got = e.tile_apply(&[(&u, &v)], &[&om]).unwrap();
    let expect = matmul(&u, &matmul_tn(&v, &om));
    assert!(got[0].sub(&expect).norm_max() < 1e-10);
}

#[test]
fn pjrt_left_sampler_matches_native_sampler() {
    let Some(e) = engine() else { return };
    let (tlr, _) = covariance_tlr(256, 64, 1e-6, 21);
    // Mid-factorization state is not needed for agreement: both samplers
    // evaluate the same expression over the same tiles.
    let native = h2opus_tlr::factor::sample::LeftSampler::new(&tlr, 3, 1);
    let pjrt = h2opus_tlr::runtime::PjrtLeftSampler::new(&tlr, 3, 1, &e);
    let mut rng = Rng::new(22);
    let om = rng.normal_matrix(64, 8);
    let d = native.sample(&om).sub(&pjrt.sample(&om)).norm_max();
    assert!(d < 1e-10, "forward sample diff {d}");
    let omt = rng.normal_matrix(64, 8);
    let dt = native.sample_t(&omt).sub(&pjrt.sample_t(&omt)).norm_max();
    assert!(dt < 1e-10, "transpose sample diff {dt}");
}

#[test]
fn cholesky_pjrt_backend_agrees_with_native() {
    let Some(e) = engine() else { return };
    let (tlr, dense) = covariance_tlr(256, 64, 1e-6, 23);
    let opts = FactorOpts { eps: 1e-6, bs: 8, ..Default::default() };
    let fn_ = cholesky(tlr.clone(), &opts).unwrap();
    let fp = cholesky_with(tlr, &opts, Backend::Pjrt(&e)).unwrap();
    // Same RNG streams, numerically near-identical chains ⇒ the factors
    // agree to well below the compression threshold.
    let ln = fn_.l.to_dense_lower();
    let lp = fp.l.to_dense_lower();
    let diff = ln.sub(&lp).norm_fro() / ln.norm_fro();
    assert!(diff < 1e-6, "backend divergence {diff}");
    // And both reconstruct A.
    let r = matmul_nt(&lp, &lp).sub(&dense).norm_fro() / dense.norm_fro();
    assert!(r < 1e-3, "pjrt factor residual {r}");
    // The artifacts were actually exercised.
    let st = e.stats();
    assert!(st.launches > 0, "pjrt path was never hit");
    assert!(st.compiled >= 1);
}

#[test]
fn ldlt_pjrt_backend_agrees_with_native() {
    let Some(e) = engine() else { return };
    let (tlr, _) = covariance_tlr(192, 48, 1e-6, 24);
    let opts = FactorOpts { eps: 1e-6, bs: 8, ..Default::default() };
    let fn_ = ldlt(tlr.clone(), &opts).unwrap();
    let fp = ldlt_with(tlr, &opts, Backend::Pjrt(&e)).unwrap();
    let ln = fn_.l.to_dense_lower();
    let lp = fp.l.to_dense_lower();
    let diff = ln.sub(&lp).norm_fro() / ln.norm_fro();
    assert!(diff < 1e-6, "ldl backend divergence {diff}");
    let dd: f64 = fn_
        .diag_flat()
        .iter()
        .zip(fp.diag_flat())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(dd < 1e-8, "ldl diagonal divergence {dd}");
}

#[test]
fn oversize_ranks_fall_back_to_native() {
    let Some(e) = engine() else { return };
    // Rank 40 exceeds every artifact variant (k ≤ 32): the sampler must
    // silently take the native path and still be correct.
    let (mut tlr, _) = covariance_tlr(256, 64, 1e-6, 25);
    let mut rng = Rng::new(26);
    let fat = h2opus_tlr::tlr::tile::LowRank {
        u: rng.normal_matrix(64, 40),
        v: rng.normal_matrix(64, 40),
    };
    tlr.set_tile(2, 0, h2opus_tlr::tlr::tile::Tile::LowRank(fat));
    let native = h2opus_tlr::factor::sample::LeftSampler::new(&tlr, 2, 1);
    let pjrt = h2opus_tlr::runtime::PjrtLeftSampler::new(&tlr, 2, 1, &e);
    let om = rng.normal_matrix(64, 8);
    let d = native.sample(&om).sub(&pjrt.sample(&om)).norm_max();
    assert!(d < 1e-10, "fallback diff {d}");
}
