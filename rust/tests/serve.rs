//! Integration tests for the `serve/` subsystem. Properties run on the
//! in-tree proptest runner ([`h2opus_tlr::testing`]): strategies
//! generate whole scenarios (frames + corruptions, shard-map mutation
//! sequences, DRR arrival orders), failures shrink to a minimal
//! counterexample, and the seed printed on failure can be pinned in
//! `proptest-regressions/serve.txt` so it replays forever:
//!
//! * serialization round trips are **bitwise**: random TLR matrices
//!   (f64 and packed-f32 tiles) and real Cholesky/LDLᵀ factors survive
//!   save → load with every tile payload exactly equal;
//! * arbitrary corruption (bit flips, truncation, scribbles) makes both
//!   the owned decoder and the mapped loader error — never panic;
//! * shard maps survive arbitrary add/remove sequences with a total
//!   owner table and minimal disruption, and decode arbitrary text
//!   without panicking;
//! * blocked multi-RHS solves match column-wise single solves to 1e-13;
//! * the [`SolveService`] coalesces ≥16 single-RHS requests into one
//!   blocked solve, loading the factor from a store written on disk —
//!   and the `serve` CLI proves the fresh-process path end to end.

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, ldlt, FactorOpts, Pivoting};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::serve::store::{
    decode_chol, decode_ldl, decode_tlr, encode_chol, encode_ldl, encode_tlr,
};
use h2opus_tlr::serve::{
    FactorStore, ServeError, ServeOpts, ShardMap, ShardedService, SolveService, StoredFactor,
};
use h2opus_tlr::solve::{
    chol_solve, chol_solve_multi, ldl_solve, ldl_solve_multi, pcg, pcg_multi, tlr_matvec,
    tlr_matvec_multi, tlr_trsm_lower, tlr_trsv_lower, TlrOp,
};
use h2opus_tlr::testing::proptest::{no_panic, run_prop, run_prop_with, Config, Strategy};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use h2opus_tlr::tlr::tile::{LowRank, LowRank32, Tile};
use h2opus_tlr::{Matrix, TlrMatrix};
use std::path::PathBuf;
use std::time::Duration;

/// Pinned counterexample seeds, replayed before any fresh generation.
const REGRESSIONS: &str = include_str!("proptest-regressions/serve.txt");

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("h2opus_serve_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Random symmetric TLR matrix with per-tile random ranks. With
/// `mixed`, roughly half the off-diagonal tiles are stored as packed
/// f32 ([`Tile::LowRank32`]) so the store's v2 per-tile precision
/// words are exercised.
fn random_tlr_with(rng: &mut Rng, nb: usize, mixed: bool) -> TlrMatrix {
    let sizes: Vec<usize> = (0..nb).map(|_| 3 + rng.below(10)).collect();
    let mut offsets = vec![0usize];
    for &s in &sizes {
        offsets.push(offsets.last().unwrap() + s);
    }
    let mut tiles = Vec::new();
    for i in 0..nb {
        for j in 0..=i {
            if i == j {
                let mut d = rng.normal_matrix(sizes[i], sizes[i]);
                d.symmetrize();
                tiles.push(Tile::Dense(d));
            } else {
                let k = rng.below(1 + sizes[i].min(sizes[j]));
                let lr = LowRank {
                    u: rng.normal_matrix(sizes[i], k),
                    v: rng.normal_matrix(sizes[j], k),
                };
                if mixed && rng.uniform() < 0.5 {
                    tiles.push(Tile::LowRank32(LowRank32::from_f64(&lr)));
                } else {
                    tiles.push(Tile::LowRank(lr));
                }
            }
        }
    }
    TlrMatrix::from_tiles(offsets, tiles)
}

fn random_tlr(rng: &mut Rng, nb: usize) -> TlrMatrix {
    random_tlr_with(rng, nb, false)
}

/// Small 2D covariance TLR matrix (the factor tests' recipe).
fn tlr_cov(n: usize, m: usize, eps: f64, seed: u64) -> TlrMatrix {
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, m);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    build_tlr(&cov, &c.offsets, &BuildOpts { eps, method: Compression::Svd, seed })
}

fn assert_tiles_bitwise(a: &TlrMatrix, b: &TlrMatrix, ctx: &str) {
    assert_eq!(a.offsets(), b.offsets(), "{ctx}: offsets");
    for i in 0..a.nb() {
        for j in 0..=i {
            match (a.tile(i, j), b.tile(i, j)) {
                (Tile::Dense(x), Tile::Dense(y)) => {
                    assert_eq!(x, y, "{ctx}: tile ({i},{j})");
                }
                (Tile::LowRank(x), Tile::LowRank(y)) => {
                    assert_eq!(x.u, y.u, "{ctx}: tile ({i},{j}) U");
                    assert_eq!(x.v, y.v, "{ctx}: tile ({i},{j}) V");
                }
                (Tile::LowRank32(x), Tile::LowRank32(y)) => {
                    assert_eq!(x.u, y.u, "{ctx}: tile ({i},{j}) U32");
                    assert_eq!(x.v, y.v, "{ctx}: tile ({i},{j}) V32");
                }
                _ => panic!("{ctx}: tile ({i},{j}) kind changed"),
            }
        }
    }
}

fn assert_cols_close(panel: &Matrix, j: usize, single: &[f64], tol: f64, ctx: &str) {
    let scale = single.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1.0);
    let err: f64 = panel
        .col(j)
        .iter()
        .zip(single)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(err <= tol * scale, "{ctx}: col {j} err {err} > {tol} * {scale}");
}

// ----------------------------------------------- proptest strategies

/// One mutation of a byte frame. Offsets are raw `u64`s reduced modulo
/// the frame length at application time (frame lengths vary per case),
/// shrinking toward offset 0 / single-bit / single-byte mutations.
#[derive(Clone, Debug)]
enum CorruptOp {
    /// Cut the frame to `at % (len + 1)` bytes.
    Truncate { at: u64 },
    /// XOR bit `bit` of byte `at % len`.
    FlipBit { at: u64, bit: u8 },
    /// Overwrite up to 16 bytes starting at `at % len`.
    Scribble { at: u64, bytes: Vec<u8> },
}

fn apply_corruption(frame: &[u8], op: &CorruptOp) -> Vec<u8> {
    match op {
        CorruptOp::Truncate { at } => frame[..*at as usize % (frame.len() + 1)].to_vec(),
        CorruptOp::FlipBit { at, bit } => {
            let mut c = frame.to_vec();
            let i = *at as usize % c.len();
            c[i] ^= 1 << (bit % 8);
            c
        }
        CorruptOp::Scribble { at, bytes } => {
            let mut c = frame.to_vec();
            let i = *at as usize % c.len();
            for (k, &b) in bytes.iter().enumerate().take(c.len() - i) {
                c[i + k] = b;
            }
            c
        }
    }
}

fn shrink_corrupt_op(op: &CorruptOp) -> Vec<CorruptOp> {
    let mut out = Vec::new();
    match op {
        CorruptOp::Truncate { at } if *at > 0 => {
            out.push(CorruptOp::Truncate { at: 0 });
            out.push(CorruptOp::Truncate { at: at / 2 });
        }
        CorruptOp::Truncate { .. } => {}
        CorruptOp::FlipBit { at, bit } => {
            if *at > 0 {
                out.push(CorruptOp::FlipBit { at: 0, bit: *bit });
                out.push(CorruptOp::FlipBit { at: at / 2, bit: *bit });
            }
            if *bit > 0 {
                out.push(CorruptOp::FlipBit { at: *at, bit: 0 });
            }
        }
        CorruptOp::Scribble { at, bytes } => {
            if bytes.len() > 1 {
                out.push(CorruptOp::Scribble { at: *at, bytes: bytes[..1].to_vec() });
                out.push(CorruptOp::Scribble {
                    at: *at,
                    bytes: bytes[..bytes.len() / 2].to_vec(),
                });
            }
            if *at > 0 {
                out.push(CorruptOp::Scribble { at: at / 2, bytes: bytes.clone() });
            }
        }
    }
    out
}

fn gen_corrupt_op(rng: &mut Rng) -> CorruptOp {
    match rng.below(3) {
        0 => CorruptOp::Truncate { at: rng.next_u64() },
        1 => CorruptOp::FlipBit { at: rng.next_u64(), bit: rng.below(8) as u8 },
        _ => CorruptOp::Scribble {
            at: rng.next_u64(),
            bytes: (0..1 + rng.below(16)).map(|_| rng.below(256) as u8).collect(),
        },
    }
}

/// A whole round-trip scenario: the matrix is reconstructed from
/// `seed`/`nb`/`mixed` inside the property, so the value stays small
/// enough to print and shrink.
#[derive(Clone, Debug)]
struct TlrSpec {
    seed: u64,
    nb: usize,
    mixed: bool,
}

struct TlrSpecStrategy;
impl Strategy for TlrSpecStrategy {
    type Value = TlrSpec;
    fn generate(&self, rng: &mut Rng) -> TlrSpec {
        TlrSpec { seed: rng.next_u64(), nb: 1 + rng.below(6), mixed: rng.uniform() < 0.5 }
    }
    fn shrink(&self, v: &TlrSpec) -> Vec<TlrSpec> {
        let mut out = Vec::new();
        if v.nb > 1 {
            out.push(TlrSpec { nb: 1, ..v.clone() });
            out.push(TlrSpec { nb: v.nb - 1, ..v.clone() });
        }
        if v.mixed {
            out.push(TlrSpec { mixed: false, ..v.clone() });
        }
        out
    }
}

/// A frame plus one corruption of it.
#[derive(Clone, Debug)]
struct FrameCorruption {
    frame: TlrSpec,
    op: CorruptOp,
}

struct FrameCorruptionStrategy;
impl Strategy for FrameCorruptionStrategy {
    type Value = FrameCorruption;
    fn generate(&self, rng: &mut Rng) -> FrameCorruption {
        let frame =
            TlrSpec { seed: rng.next_u64(), nb: 1 + rng.below(4), mixed: rng.uniform() < 0.5 };
        FrameCorruption { frame, op: gen_corrupt_op(rng) }
    }
    fn shrink(&self, v: &FrameCorruption) -> Vec<FrameCorruption> {
        let mut out: Vec<FrameCorruption> = TlrSpecStrategy
            .shrink(&v.frame)
            .into_iter()
            .map(|frame| FrameCorruption { frame, op: v.op.clone() })
            .collect();
        out.extend(
            shrink_corrupt_op(&v.op)
                .into_iter()
                .map(|op| FrameCorruption { frame: v.frame.clone(), op }),
        );
        out
    }
}

// ------------------------------------------------ serialization props

#[test]
fn prop_tlr_roundtrip_bitwise() {
    run_prop("tlr_roundtrip", REGRESSIONS, &TlrSpecStrategy, |s| {
        let mut rng = Rng::new(s.seed);
        let a = random_tlr_with(&mut rng, s.nb, s.mixed);
        let back = decode_tlr(&encode_tlr(&a)).map_err(|e| format!("decode failed: {e:?}"))?;
        no_panic("bitwise tile compare", || assert_tiles_bitwise(&a, &back, "roundtrip"))
    });
}

#[test]
fn chol_factor_roundtrip_bitwise_with_pivoting() {
    let tlr = tlr_cov(200, 50, 1e-8, 21);
    let f = cholesky(
        tlr,
        &FactorOpts { eps: 1e-8, bs: 8, pivot: Pivoting::Frobenius, ..Default::default() },
    )
    .unwrap();
    let dir = temp_dir("chol_rt");
    let path = dir.join("f.bin");
    h2opus_tlr::serve::store::save_chol(&path, &f).unwrap();
    let back = h2opus_tlr::serve::store::load_chol(&path).unwrap();
    assert_tiles_bitwise(&f.l, &back.l, "chol");
    assert_eq!(f.stats.perm, back.stats.perm, "tile permutation");
    assert_eq!(f.scalar_perm(), back.scalar_perm(), "scalar permutation");
    // In-memory encode agrees with the file path.
    assert_eq!(encode_chol(&f), std::fs::read(&path).unwrap());
    let _ = decode_chol(&encode_chol(&f)).unwrap();
    // The loaded factor solves identically (bitwise inputs → 1e-13).
    let mut rng = Rng::new(22);
    let b: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
    let x0 = chol_solve(&f, &b);
    let x1 = chol_solve(&back, &b);
    let panel = Matrix::from_vec(200, 1, x1);
    assert_cols_close(&panel, 0, &x0, 1e-13, "loaded-factor solve");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ldl_factor_roundtrip_bitwise() {
    let tlr = tlr_cov(160, 40, 1e-8, 23);
    let f = ldlt(tlr, &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() }).unwrap();
    let bytes = encode_ldl(&f);
    let back = decode_ldl(&bytes).unwrap();
    assert_tiles_bitwise(&f.l, &back.l, "ldl");
    assert_eq!(f.d, back.d, "block diagonal");
}

// ----------------------------------------------------- blocked solves

#[test]
fn multi_solves_match_columnwise_singles() {
    let tlr = tlr_cov(256, 64, 1e-9, 24);
    let fc = cholesky(tlr.clone(), &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() })
        .unwrap();
    let fl = ldlt(tlr.clone(), &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() }).unwrap();
    let mut rng = Rng::new(25);
    for &r in &[1usize, 3, 16] {
        let b = rng.normal_matrix(256, r);
        let xc = chol_solve_multi(&fc, &b);
        let xl = ldl_solve_multi(&fl, &b);
        let ym = tlr_matvec_multi(&tlr, &b);
        let tm = tlr_trsm_lower(&fc.l, &b);
        for j in 0..r {
            let ctx = format!("r={r}");
            assert_cols_close(&xc, j, &chol_solve(&fc, b.col(j)), 1e-13, &format!("{ctx} chol"));
            assert_cols_close(&xl, j, &ldl_solve(&fl, b.col(j)), 1e-13, &format!("{ctx} ldl"));
            let mv = tlr_matvec(&tlr, b.col(j));
            assert_cols_close(&ym, j, &mv, 1e-13, &format!("{ctx} matvec"));
            let tv = tlr_trsv_lower(&fc.l, b.col(j));
            assert_cols_close(&tm, j, &tv, 1e-13, &format!("{ctx} trsm"));
        }
    }
}

#[test]
fn blocked_pcg_matches_columnwise_single() {
    let tlr = tlr_cov(200, 50, 1e-9, 26);
    let opts = FactorOpts { eps: 1e-3, bs: 8, shift: 1e-3, ..Default::default() };
    let f = cholesky(tlr.clone(), &opts).unwrap();
    let mut rng = Rng::new(27);
    let r = 4;
    let b = rng.normal_matrix(200, r);
    let op = TlrOp(&tlr);
    let minv_panel = |res: &Matrix| chol_solve_multi(&f, res);
    let multi = pcg_multi(&op, &minv_panel, &b, 1e-9, 200);
    for j in 0..r {
        let single = pcg(&op, &|res| chol_solve(&f, res), b.col(j), 1e-9, 200);
        assert!(multi.converged[j] && single.converged, "col {j}");
        // Iteration counts may differ by at most rounding at the tol
        // boundary (the exact per-column match is asserted
        // deterministically in solve::cg's unit tests).
        assert!(
            multi.iters[j].abs_diff(single.iters) <= 1,
            "col {j}: {} vs {} iterations",
            multi.iters[j],
            single.iters
        );
        let panel = &multi.x;
        assert_cols_close(panel, j, &single.x, 1e-6, "pcg");
    }
}

// ----------------------------------------------------------- service

#[test]
fn service_coalesces_16_requests_into_one_blocked_solve() {
    let n = 256;
    let tlr = tlr_cov(n, 64, 1e-9, 28);
    let f = cholesky(tlr, &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() }).unwrap();
    let dir = temp_dir("svc");
    let key = 0xFACADEu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f, "test factor").unwrap();
    // The service gets its own store handle: the factor crosses only
    // through the disk format.
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 16,
            flush_deadline: Duration::from_millis(2000),
            cache_capacity: 2,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(29);
    let rhss: Vec<Vec<f64>> =
        (0..16).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let tickets: Vec<_> =
        rhss.iter().map(|b| service.submit(key, b.clone()).unwrap()).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let resp = t.wait().unwrap();
        assert_eq!(resp.panel_width, 16, "request {i} not coalesced");
        let single = chol_solve(&f, &rhss[i]);
        let panel = Matrix::from_vec(n, 1, resp.x);
        assert_cols_close(&panel, 0, &single, 1e-13, &format!("request {i}"));
        assert!(resp.latency > Duration::ZERO);
    }
    let stats = service.stats();
    assert_eq!(stats.requests, 16);
    assert_eq!(stats.batches, 1, "16 requests must run as one blocked solve");
    assert_eq!(stats.max_panel, 16);
    assert!((stats.mean_panel_width() - 16.0).abs() < 1e-9);
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn service_reports_unknown_key_and_bad_rhs() {
    let n = 160;
    let tlr = tlr_cov(n, 40, 1e-8, 30);
    let f = ldlt(tlr, &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() }).unwrap();
    let dir = temp_dir("svc_err");
    let key = 0xBEEFu64;
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts { max_panel: 4, flush_deadline: Duration::from_millis(5), ..Default::default() },
    );
    // Unknown key: the store is empty.
    match service.submit(0xDEAD, vec![0.0; n]).unwrap().wait() {
        Err(ServeError::UnknownFactor(k)) => assert_eq!(k, 0xDEAD),
        other => panic!("expected UnknownFactor, got {other:?}"),
    }
    // Register in memory (no disk write) and solve through the registry,
    // including a malformed RHS alongside a valid one.
    service.register(key, StoredFactor::Ldl(f));
    let bad = service.submit(key, vec![1.0; n + 3]).unwrap();
    let good = service.submit(key, vec![1.0; n]).unwrap();
    match bad.wait() {
        Err(ServeError::BadRhs { expected, got }) => {
            assert_eq!(expected, n);
            assert_eq!(got, n + 3);
        }
        other => panic!("expected BadRhs, got {other:?}"),
    }
    let resp = good.wait().unwrap();
    assert_eq!(resp.x.len(), n);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn factor_store_keys_and_missing() {
    let dir = temp_dir("store_keys");
    let store = FactorStore::open(&dir).unwrap();
    assert!(store.load(42).unwrap().is_none());
    assert!(!store.contains(42));
    let tlr = tlr_cov(128, 32, 1e-6, 31);
    let f = cholesky(tlr, &FactorOpts { eps: 1e-6, bs: 8, ..Default::default() }).unwrap();
    store.save_chol(7, &f, "seven").unwrap();
    store.save_chol(9, &f, "nine").unwrap();
    assert!(store.contains(7));
    assert_eq!(store.keys().unwrap(), vec![7, 9]);
    match store.load(7).unwrap() {
        Some(StoredFactor::Chol(back)) => assert_tiles_bitwise(&f.l, &back.l, "store"),
        other => panic!("expected Chol factor, got {:?}", other.map(|f| f.n())),
    }
    // A key holds exactly one factor: saving the other kind replaces it.
    let tlr2 = tlr_cov(128, 32, 1e-6, 31);
    let fl = ldlt(tlr2, &FactorOpts { eps: 1e-6, bs: 8, ..Default::default() }).unwrap();
    store.save_ldl(7, &fl, "seven-ldl").unwrap();
    match store.load(7).unwrap() {
        Some(StoredFactor::Ldl(back)) => assert_eq!(fl.d, back.d),
        _ => panic!("save_ldl must replace the chol factor under the same key"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------- zero-copy mmap loading

#[test]
fn mapped_chol_load_is_zero_copy_and_solves_bitwise_identical() {
    let tlr = tlr_cov(256, 64, 1e-8, 50);
    let f = cholesky(tlr.clone(), &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() })
        .unwrap();
    let dir = temp_dir("mmap_chol");
    let key = 0xA11CEu64;
    let store = FactorStore::open(&dir).unwrap();
    store.save_chol(key, &f, "mmap test").unwrap();
    store.save_matrix(key, &tlr).unwrap();
    assert!(store.contains_matrix(key));

    let owned = match store.load(key).unwrap().unwrap() {
        StoredFactor::Chol(c) => c,
        _ => panic!("expected chol"),
    };
    let mapped = store.load_mapped(key).unwrap().unwrap();
    let mc = match &mapped.value {
        StoredFactor::Chol(c) => c,
        _ => panic!("expected chol"),
    };
    assert_tiles_bitwise(&owned.l, &mc.l, "mapped vs owned");
    assert_eq!(owned.stats.perm, mc.stats.perm);

    if h2opus_tlr::serve::mmap::SUPPORTS_ZERO_COPY {
        // No f64 payload copy: every tile payload points inside the
        // mapping.
        assert!(mc.l.is_fully_mapped(), "every tile must be a mapped view");
        assert!(mapped.mapped_bytes >= 40);
        for i in 0..mc.l.nb() {
            for j in 0..=i {
                match mc.l.tile(i, j) {
                    Tile::Dense(m) => {
                        assert!(
                            mapped.contains_ptr(m.as_slice().as_ptr()),
                            "dense tile ({i},{j}) data must lie inside the mapping"
                        );
                    }
                    Tile::LowRank(lr) if lr.rank() > 0 => {
                        assert!(mapped.contains_ptr(lr.u.as_slice().as_ptr()));
                        assert!(mapped.contains_ptr(lr.v.as_slice().as_ptr()));
                    }
                    Tile::LowRank(_) => {}
                }
            }
        }
    }

    // Mapped-backed solves are bitwise identical to owned-backed ones.
    let mut rng = Rng::new(51);
    let b = rng.normal_matrix(256, 7);
    let xo = chol_solve_multi(&owned, &b);
    let xm = chol_solve_multi(mc, &b);
    assert_eq!(xo.as_slice(), xm.as_slice(), "mapped chol solve must be bitwise identical");

    // Same for pcg_multi, with both the operator and the preconditioner
    // coming from the mapped path.
    let ao = store.load_matrix(key).unwrap().unwrap();
    let am = store.load_matrix_mapped(key).unwrap().unwrap();
    assert_tiles_bitwise(&ao, &am.value, "mapped vs owned operator");
    let minv_o = |r: &Matrix| chol_solve_multi(&owned, r);
    let minv_m = |r: &Matrix| chol_solve_multi(mc, r);
    let po = pcg_multi(&TlrOp(&ao), &minv_o, &b, 1e-8, 100);
    let pm = pcg_multi(&TlrOp(&am.value), &minv_m, &b, 1e-8, 100);
    assert_eq!(po.iters, pm.iters);
    assert_eq!(po.converged, pm.converged);
    assert_eq!(po.x.as_slice(), pm.x.as_slice(), "mapped pcg must be bitwise identical");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mapped_ldl_load_is_zero_copy_and_solves_bitwise_identical() {
    let tlr = tlr_cov(160, 40, 1e-8, 52);
    let f = ldlt(tlr, &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() }).unwrap();
    let dir = temp_dir("mmap_ldl");
    let key = 0x1D1u64;
    let store = FactorStore::open(&dir).unwrap();
    store.save_ldl(key, &f, "mmap ldl").unwrap();
    let owned = match store.load(key).unwrap().unwrap() {
        StoredFactor::Ldl(l) => l,
        _ => panic!("expected ldl"),
    };
    let mapped = store.load_mapped(key).unwrap().unwrap();
    let ml = match &mapped.value {
        StoredFactor::Ldl(l) => l,
        _ => panic!("expected ldl"),
    };
    assert_tiles_bitwise(&owned.l, &ml.l, "mapped vs owned ldl");
    assert_eq!(owned.d, ml.d);
    if h2opus_tlr::serve::mmap::SUPPORTS_ZERO_COPY {
        assert!(ml.l.is_fully_mapped());
    }
    let mut rng = Rng::new(53);
    let b = rng.normal_matrix(160, 5);
    let xo = ldl_solve_multi(&owned, &b);
    let xm = ldl_solve_multi(ml, &b);
    assert_eq!(xo.as_slice(), xm.as_slice(), "mapped ldl solve must be bitwise identical");
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------- store corruption props

/// Arbitrary corruption of arbitrary frames (f64 and packed-f32
/// tiles): the owned decoder and the mapped loader both return a typed
/// error — never panic, never accept a mutated frame.
#[test]
fn prop_store_corruption_never_panics_owned_or_mapped() {
    use h2opus_tlr::serve::store::load_tlr_mapped;
    let dir = temp_dir("corrupt_prop");
    let path = dir.join("c.bin");
    run_prop("store_corruption", REGRESSIONS, &FrameCorruptionStrategy, |c| {
        let mut rng = Rng::new(c.frame.seed);
        let a = random_tlr_with(&mut rng, c.frame.nb, c.frame.mixed);
        let bytes = encode_tlr(&a);
        let corrupt = apply_corruption(&bytes, &c.op);
        if corrupt == bytes {
            return Ok(()); // e.g. a scribble that rewrote identical bytes
        }
        no_panic("decode_tlr on corrupt frame", || decode_tlr(&corrupt))?;
        if decode_tlr(&corrupt).is_ok() {
            return Err("owned decoder accepted a corrupted frame".into());
        }
        std::fs::write(&path, &corrupt).map_err(|e| format!("write: {e}"))?;
        no_panic("load_tlr_mapped on corrupt frame", || load_tlr_mapped(&path))?;
        if load_tlr_mapped(&path).is_ok() {
            return Err("mapped loader accepted a corrupted frame".into());
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// Deterministic exhaustive companion to the random property: one
/// small mixed-precision frame, every 8-byte truncation and every
/// byte flipped once, through both loaders.
#[test]
fn store_corruption_exhaustive_on_small_frame() {
    use h2opus_tlr::serve::store::load_tlr_mapped;
    let dir = temp_dir("corrupt_exhaustive");
    let path = dir.join("c.bin");
    let mut rng = Rng::new(0xBAD0);
    let a = random_tlr_with(&mut rng, 3, true);
    let bytes = encode_tlr(&a);
    for cut in (0..bytes.len()).step_by(8) {
        assert!(decode_tlr(&bytes[..cut]).is_err(), "cut={cut}");
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(load_tlr_mapped(&path).is_err(), "mapped cut={cut}");
    }
    for at in 0..bytes.len() {
        let mut corrupt = bytes.clone();
        corrupt[at] ^= 1 << rng.below(8);
        assert!(decode_tlr(&corrupt).is_err(), "flip at byte {at}");
        // The mapped loader round-trips through the disk; sample it.
        if at % 7 == 0 {
            std::fs::write(&path, &corrupt).unwrap();
            assert!(load_tlr_mapped(&path).is_err(), "mapped flip at {at}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------- multi-tenancy and fairness

/// One small Cholesky factor the tenancy tests can clone freely.
fn small_factor(seed: u64) -> h2opus_tlr::factor::CholFactor {
    let tlr = tlr_cov(128, 32, 1e-6, seed);
    cholesky(tlr, &FactorOpts { eps: 1e-6, bs: 8, ..Default::default() }).unwrap()
}

#[test]
fn admission_control_rejects_over_backlog_with_typed_error() {
    let n = 128;
    let f = small_factor(90);
    let dir = temp_dir("admission");
    let (ka, kb) = (0xAAAAu64, 0xBBBBu64);
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 64,
            flush_deadline: Duration::from_millis(400),
            max_backlog: 4,
            ..Default::default()
        },
    );
    service.register(ka, StoredFactor::Chol(f.clone()));
    service.register(kb, StoredFactor::Chol(f));
    let mut rng = Rng::new(91);
    let mut rhs = || -> Vec<f64> { (0..n).map(|_| rng.normal()).collect() };
    // Occupy the worker: key A's panel holds open for the deadline.
    let ta = service.submit(ka, rhs()).unwrap();
    std::thread::sleep(Duration::from_millis(30));
    // Key B may queue exactly `max_backlog` requests...
    let tb: Vec<_> = (0..4).map(|_| service.submit(kb, rhs()).unwrap()).collect();
    // ...and the next submission is rejected with a typed error, not
    // queued unboundedly.
    match service.submit(kb, rhs()) {
        Err(ServeError::Overloaded { key, backlog, limit }) => {
            assert_eq!(key, kb);
            assert_eq!(backlog, 4);
            assert_eq!(limit, 4);
        }
        other => panic!("expected Overloaded, got {:?}", other.map(|_| ())),
    }
    assert_eq!(service.stats().rejected, 1);
    // Every admitted request is still answered.
    assert_eq!(ta.wait().unwrap().x.len(), n);
    for t in tb {
        let resp = t.wait().unwrap();
        assert_eq!(resp.panel_width, 4);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// An arbitrary interleaving of minority (`true`) and hog (`false`)
/// submissions staged behind the pilot hold. Counts are constrained so
/// the schedule stays in the deterministic-DRR regime: ≥ 9 minority
/// requests force ≥ 2 minority panels at quantum 8, and neither
/// backlog reaches the 64-column panel that would allow an early
/// flush. Shrinks by dropping submissions (hogs first).
#[derive(Clone, Debug)]
struct DrrArrivals {
    order: Vec<bool>,
}

impl DrrArrivals {
    fn minority(&self) -> usize {
        self.order.iter().filter(|&&m| m).count()
    }
    fn hog(&self) -> usize {
        self.order.len() - self.minority()
    }
}

struct DrrArrivalsStrategy;
impl Strategy for DrrArrivalsStrategy {
    type Value = DrrArrivals;
    fn generate(&self, rng: &mut Rng) -> DrrArrivals {
        let m = 9 + rng.below(8); // 9..=16 minority requests
        let h = 17 + rng.below(24); // 17..=40 hog requests
        let mut order: Vec<bool> = (0..m + h).map(|i| i < m).collect();
        // Fisher–Yates: an arbitrary arrival interleaving.
        for i in (1..order.len()).rev() {
            order.swap(i, rng.below(i + 1));
        }
        DrrArrivals { order }
    }
    fn shrink(&self, v: &DrrArrivals) -> Vec<DrrArrivals> {
        let mut out = Vec::new();
        let mut drop_one = |keep_minority: bool| {
            if let Some(i) = v.order.iter().position(|&m| m != keep_minority) {
                let mut order = v.order.clone();
                order.remove(i);
                out.push(DrrArrivals { order });
            }
        };
        if v.hog() > 0 {
            drop_one(true); // remove the first hog submission
        }
        if v.minority() > 9 {
            drop_one(false); // remove the first minority submission
        }
        // Canonical order: all minority first (the original test's shape).
        let mut sorted = v.order.clone();
        sorted.sort_unstable_by_key(|&m| !m);
        if sorted != v.order {
            out.push(DrrArrivals { order: sorted });
        }
        out
    }
}

/// The DRR quantum bound holds for **any** arrival order: between any
/// two consecutive minority panels, the hog is served at most one
/// quantum (8 columns). Arrival order within a key only permutes that
/// key's FIFO; the cross-key interleave must never buy the hog a
/// second round while the minority has work queued.
#[test]
fn drr_quantum_bounds_hog_columns_between_minority_panels() {
    let n = 128;
    let f = small_factor(92);
    let dir = temp_dir("drr_quantum");
    let (kc, kh, km) = (0xCC0u64, 0xB06u64, 0x111u64);
    // Service churn per case is real wall-clock (a 500 ms pilot hold
    // each), so the sweep runs few fresh cases; pinned seeds and the
    // fixed base seed keep it deterministic.
    // Shrinking re-runs the service per candidate, so the step budget
    // is tight too (a failure still shrinks, just less exhaustively).
    let cfg = Config { cases: 4, max_shrink_steps: 40 };
    run_prop_with(cfg, "drr_arrivals", REGRESSIONS, &DrrArrivalsStrategy, |arrivals| {
        // quantum (8) < max_panel (64): the staged backlogs (≤ 16 and
        // ≤ 40) never reach a full panel, so the work-conserving early
        // flush cannot trigger while requests stage behind the pilot
        // hold, and the post-pilot schedule is fully deterministic DRR.
        let service = SolveService::start(
            FactorStore::open(&dir).unwrap(),
            ServeOpts {
                max_panel: 64,
                quantum: 8,
                flush_deadline: Duration::from_millis(500),
                max_backlog: 100_000,
                ..Default::default()
            },
        );
        service.register(kc, StoredFactor::Chol(f.clone()));
        service.register(kh, StoredFactor::Chol(f.clone()));
        service.register(km, StoredFactor::Chol(f.clone()));
        let mut rng = Rng::new(93);
        let mut rhs = || -> Vec<f64> { (0..n).map(|_| rng.normal()).collect() };
        // Pilot request: the worker schedules key C and holds its
        // sub-panel batch open for the 500 ms deadline, during which
        // both tenants queue up in the generated arrival order.
        let tc = service.submit(kc, rhs()).map_err(|e| format!("pilot: {e:?}"))?;
        std::thread::sleep(Duration::from_millis(50));
        let tickets: Vec<_> = arrivals
            .order
            .iter()
            .map(|&minority| {
                let key = if minority { km } else { kh };
                service.submit(key, rhs()).map_err(|e| format!("submit: {e:?}"))
            })
            .collect::<Result<_, _>>()?;
        tc.wait().map_err(|e| format!("pilot wait: {e:?}"))?;
        for t in tickets {
            t.wait().map_err(|e| format!("wait: {e:?}"))?;
        }
        // DRR bound: between any two consecutive minority panels the
        // hog gets at most one quantum (8 columns) — the rotation never
        // gives the hog two rounds while the minority has work queued.
        let log = service.served_log();
        if log.first().map(|b| b.key) != Some(kc) {
            return Err("pilot panel must be served first".into());
        }
        let min_panels: Vec<usize> = log
            .iter()
            .enumerate()
            .filter(|(_, b)| b.key == km)
            .map(|(i, _)| i)
            .collect();
        if min_panels.len() < 2 {
            return Err(format!(
                "{} minority requests at quantum 8 need >= 2 panels",
                arrivals.minority()
            ));
        }
        for pair in min_panels.windows(2) {
            let hog_cols: usize = log[pair[0] + 1..pair[1]]
                .iter()
                .filter(|b| b.key == kh)
                .map(|b| b.width)
                .sum();
            if hog_cols > 8 {
                return Err(format!(
                    "hog served {hog_cols} columns between consecutive minority \
                     panels; quantum is 8"
                ));
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
#[ignore = "wall-clock latency bound; timing-sensitive on loaded CI runners — run with \
            `cargo test --release --test serve -- --ignored drr_fairness`. The scheduling \
            property behind it is asserted deterministically in \
            drr_quantum_bounds_hog_columns_between_minority_panels."]
fn drr_fairness_minority_p95_within_2x_of_solo() {
    let n = 128;
    let f = small_factor(94);
    let (kh, km) = (0x406u64, 0x107u64);
    // A trickled minority tenant (2 requests every 30 ms), optionally
    // against a hog at 10:1 offered load (20 requests per tick plus an
    // initial burst). Hog arrivals are exact panel multiples (20 and
    // 600 vs max_panel 10), so the hog's queue count stays ≡ 0 mod 10:
    // every hog panel flushes full, the hog never sits in a flush-
    // deadline hold-open, and the minority only ever waits behind full
    // hog panels — the DRR regime the 2x bound is about. Returns the
    // minority's p95 latency.
    let run = |with_hog: bool, tag: &str| -> Duration {
        let dir = temp_dir(tag);
        let service = SolveService::start(
            FactorStore::open(&dir).unwrap(),
            ServeOpts {
                max_panel: 10,
                flush_deadline: Duration::from_millis(25),
                max_backlog: 1_000_000,
                ..Default::default()
            },
        );
        service.register(km, StoredFactor::Chol(f.clone()));
        if with_hog {
            service.register(kh, StoredFactor::Chol(f.clone()));
        }
        let mut rng = Rng::new(95);
        let mut rhs = || -> Vec<f64> { (0..n).map(|_| rng.normal()).collect() };
        if with_hog {
            for _ in 0..600 {
                // Hog responses are discarded (dropped tickets).
                let _ = service.submit(kh, rhs()).unwrap();
            }
        }
        let mut tickets = Vec::new();
        for _ in 0..8 {
            if with_hog {
                for _ in 0..20 {
                    let _ = service.submit(kh, rhs()).unwrap();
                }
            }
            tickets.push(service.submit(km, rhs()).unwrap());
            tickets.push(service.submit(km, rhs()).unwrap());
            std::thread::sleep(Duration::from_millis(30));
        }
        let mut lat: Vec<Duration> =
            tickets.into_iter().map(|t| t.wait().unwrap().latency).collect();
        lat.sort();
        let p95 = lat[(lat.len() - 1) * 95 / 100];
        drop(service);
        let _ = std::fs::remove_dir_all(&dir);
        p95
    };
    let solo = run(false, "fair_solo");
    let mixed = run(true, "fair_mixed");
    // The acceptance bound: DRR keeps the minority tenant's p95 within
    // 2x its solo p95 under 10:1 offered load. Solo p95 is floored at
    // the 25 ms flush deadline: solo latency is deadline-dominated by
    // construction (sub-panel trickle), so any smaller measurement is
    // noise, and the floor keeps a shared CI runner's jitter from
    // turning a ~30 ms mixed p95 into a spurious failure. The
    // scheduling-level fairness bound is asserted deterministically in
    // `drr_quantum_bounds_hog_columns_before_minority_panel`.
    let solo_f = solo.as_secs_f64().max(0.025);
    assert!(
        mixed.as_secs_f64() <= 2.0 * solo_f,
        "minority p95 {mixed:?} exceeds 2x solo p95 {solo:?}"
    );
}

// ------------------------------------------------- pcg via the service

#[test]
fn service_routes_pcg_requests_through_panel_preconditioner() {
    let n = 200;
    let tlr = tlr_cov(n, 50, 1e-9, 80);
    let opts = FactorOpts { eps: 1e-3, bs: 8, shift: 1e-3, ..Default::default() };
    let f = cholesky(tlr.clone(), &opts).unwrap();
    let dir = temp_dir("svc_pcg");
    let key = 0x9C6u64;
    let store = FactorStore::open(&dir).unwrap();
    store.save_chol(key, &f, "pcg preconditioner").unwrap();
    store.save_matrix(key, &tlr).unwrap();
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 4,
            flush_deadline: Duration::from_millis(2000),
            ..Default::default()
        },
    );
    let mut rng = Rng::new(81);
    let b = rng.normal_matrix(n, 4);
    let tickets: Vec<_> = (0..4)
        .map(|j| service.submit_pcg(key, b.col(j).to_vec(), 1e-9, 200).unwrap())
        .collect();
    // The same panel through the direct blocked PCG.
    let minv = |r: &Matrix| chol_solve_multi(&f, r);
    let direct = pcg_multi(&TlrOp(&tlr), &minv, &b, 1e-9, 200);
    for (j, t) in tickets.into_iter().enumerate() {
        let resp = t.wait().unwrap();
        assert_eq!(resp.panel_width, 4, "pcg requests must coalesce into one panel");
        assert!(resp.converged, "col {j} converged");
        assert_eq!(resp.iters, direct.iters[j], "col {j} iterations");
        let panel = Matrix::from_vec(n, 1, resp.x);
        assert_cols_close(&direct.x, j, panel.col(0), 1e-13, &format!("pcg col {j}"));
    }
    let log = service.served_log();
    assert!(log.iter().any(|e| e.pcg), "pcg panel must be logged as pcg");
    // A key with a factor but no stored operator reports UnknownMatrix.
    let k2 = 0x9C7u64;
    service.register(k2, StoredFactor::Chol(f.clone()));
    match service.submit_pcg(k2, vec![0.0; n], 1e-9, 10).unwrap().wait() {
        Err(ServeError::UnknownMatrix(k)) => assert_eq!(k, k2),
        other => panic!("expected UnknownMatrix, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- sharded serving

/// Two keys pinned to different owners under `ShardMap::new(8, [w0,
/// w1])`: key 7 → shard 2 → w0, key 9 → shard 4 → w1 (the owner table
/// itself is pinned in `serve::shard`'s unit tests).
const SHARD_KEY_A: u64 = 7;
const SHARD_KEY_B: u64 = 9;

fn two_worker_map() -> ShardMap {
    ShardMap::new(8, vec!["w0".to_string(), "w1".to_string()])
}

/// The acceptance property: a two-shard [`ShardedService`] answers a
/// mixed-key request stream with solutions **bitwise identical** to a
/// single [`SolveService`] over the same store, and each worker's DRR
/// log contains only the keys its shards own, in full panels.
///
/// Identical answers need identical panel composition, so both runs
/// use the deterministic-coalescing idiom of the fairness tests: one
/// pilot request per key opens a long flush hold, and the remaining
/// requests are submitted *interleaved* (A, B, A, B, …) so neither
/// key's queue reaches a full panel while the other is partial — the
/// work-conserving early flush can then never cut a panel short, and
/// every panel is a full `max_panel` block taken in FIFO order per
/// key, on the single service (DRR alternates keys) and on the
/// sharded one (each worker sees only its own key) alike.
#[test]
fn two_shard_service_matches_single_service_bitwise() {
    let n = 128;
    let fa = small_factor(60);
    let fb = small_factor(61);
    let map = two_worker_map();
    assert_ne!(
        map.owner_of(SHARD_KEY_A),
        map.owner_of(SHARD_KEY_B),
        "demo keys must exercise two different shards"
    );
    let dir = temp_dir("sharded_vs_single");
    let store = FactorStore::open(&dir).unwrap();
    store.save_chol(SHARD_KEY_A, &fa, "key A").unwrap();
    store.save_chol(SHARD_KEY_B, &fb, "key B").unwrap();
    let opts = ServeOpts {
        max_panel: 4,
        flush_deadline: Duration::from_millis(2000),
        ..Default::default()
    };
    let per_key = 8; // 2 full panels per key
    let mut rng = Rng::new(62);
    let rhss_a: Vec<Vec<f64>> =
        (0..per_key).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let rhss_b: Vec<Vec<f64>> =
        (0..per_key).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    // Pilot per key, then the rest while the holds are open.
    let run = |submit: &dyn Fn(u64, Vec<f64>) -> h2opus_tlr::serve::Ticket| {
        let mut tickets = Vec::new();
        tickets.push(submit(SHARD_KEY_A, rhss_a[0].clone()));
        tickets.push(submit(SHARD_KEY_B, rhss_b[0].clone()));
        std::thread::sleep(Duration::from_millis(50));
        for (a, b) in rhss_a[1..].iter().zip(&rhss_b[1..]) {
            tickets.push(submit(SHARD_KEY_A, a.clone()));
            tickets.push(submit(SHARD_KEY_B, b.clone()));
        }
        tickets.into_iter().map(|t| t.wait().unwrap()).collect::<Vec<_>>()
    };
    let single = SolveService::start(FactorStore::open(&dir).unwrap(), opts.clone());
    let single_resps = run(&|k, b| single.submit(k, b).unwrap());
    let sharded =
        ShardedService::start_with_map(&FactorStore::open(&dir).unwrap(), opts, map.clone())
            .unwrap();
    let sharded_resps = run(&|k, b| sharded.submit(k, b).unwrap());
    for (i, (s, sh)) in single_resps.iter().zip(&sharded_resps).enumerate() {
        assert_eq!(s.panel_width, 4, "request {i}: single service panel");
        assert_eq!(sh.panel_width, 4, "request {i}: sharded service panel");
        assert_eq!(s.x, sh.x, "request {i}: sharded solve must be bitwise identical");
    }
    // Per-shard DRR state is intact: each worker's fairness log holds
    // only the keys its shards own, in full panels.
    for (worker, log) in sharded.served_log_per_worker() {
        assert_eq!(log.len(), 2, "{worker}: 8 requests at panel 4");
        for b in &log {
            assert_eq!(map.owner_of(b.key), worker, "{worker} served a foreign key");
            assert_eq!(b.width, 4, "{worker}: full panels");
        }
    }
    // Aggregated stats line up with the single service's totals.
    let agg = sharded.stats();
    let st = single.stats();
    assert_eq!(agg.requests, st.requests);
    assert_eq!(agg.panel_cols, st.panel_cols);
    assert_eq!(agg.batches, st.batches);
    assert_eq!(agg.max_panel, 4);
    drop(single);
    drop(sharded);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mixed-key fan-out via `submit_batch`, and rebalancing: adding a
/// worker remaps only the moved shards (registered keys follow), and
/// removing a worker drains its queued tickets on the old owner before
/// the thread exits.
#[test]
fn sharded_rebalance_migrates_keys_and_drains_in_flight() {
    let n = 128;
    let f = small_factor(63);
    let dir = temp_dir("sharded_rebalance");
    let store = FactorStore::open(&dir).unwrap();
    let service = ShardedService::start_with_map(
        &store,
        ServeOpts {
            max_panel: 4,
            flush_deadline: Duration::from_millis(300),
            ..Default::default()
        },
        two_worker_map(),
    )
    .unwrap();
    // Registered (in-memory) factors under both pinned keys.
    service.register(SHARD_KEY_A, StoredFactor::Chol(f.clone()));
    service.register(SHARD_KEY_B, StoredFactor::Chol(f.clone()));
    let mut rng = Rng::new(64);
    let mut rhs = || -> Vec<f64> { (0..n).map(|_| rng.normal()).collect() };
    let reqs: Vec<(u64, Vec<f64>)> = (0..6)
        .map(|i| (if i % 2 == 0 { SHARD_KEY_A } else { SHARD_KEY_B }, rhs()))
        .collect();
    let inflight = service.submit_batch(reqs);
    // Remove the worker that owns key A while its requests are queued
    // (or already solving): the departing service drains first, so
    // every ticket must resolve with a real answer, not Canceled.
    let owner_a = service.map().owner_of(SHARD_KEY_A).to_string();
    let moved = service.remove_worker(&owner_a).unwrap();
    assert!(!moved.is_empty());
    for t in inflight {
        let resp = t.unwrap().wait().expect("in-flight ticket lost in rebalance");
        assert_eq!(resp.x.len(), n);
    }
    // Key A now routes to the survivor, and its registration migrated.
    let survivor = service.map().owner_of(SHARD_KEY_A).to_string();
    assert_ne!(survivor, owner_a);
    let resp = service.submit(SHARD_KEY_A, rhs()).unwrap().wait().unwrap();
    assert_eq!(resp.x.len(), n);
    // Growing the fleet again only moves the new worker's shards.
    let before = service.map();
    let moved = service.add_worker("w9").unwrap();
    let after = service.map();
    for s in 0..before.n_shards() {
        if moved.contains(&s) {
            assert_eq!(after.owner_of_shard(s), "w9");
        } else {
            assert_eq!(after.owner_of_shard(s), before.owner_of_shard(s));
        }
    }
    // Requests on every key still answer after the second rebalance.
    for key in [SHARD_KEY_A, SHARD_KEY_B] {
        let resp = service.submit(key, rhs()).unwrap().wait().unwrap();
        assert_eq!(resp.x.len(), n, "key {key:#x} after rebalance");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------ shard map properties

/// A shard map plus one corruption of its text encoding.
#[derive(Clone, Debug)]
struct MapCorruption {
    n_shards: usize,
    n_workers: usize,
    op: CorruptOp,
}

struct MapCorruptionStrategy;
impl Strategy for MapCorruptionStrategy {
    type Value = MapCorruption;
    fn generate(&self, rng: &mut Rng) -> MapCorruption {
        MapCorruption {
            n_shards: 1 + rng.below(64),
            n_workers: 1 + rng.below(4),
            op: gen_corrupt_op(rng),
        }
    }
    fn shrink(&self, v: &MapCorruption) -> Vec<MapCorruption> {
        let mut out = Vec::new();
        if v.n_shards > 1 {
            out.push(MapCorruption { n_shards: 1, ..v.clone() });
            out.push(MapCorruption { n_shards: v.n_shards / 2, ..v.clone() });
        }
        if v.n_workers > 1 {
            out.push(MapCorruption { n_workers: v.n_workers - 1, ..v.clone() });
        }
        out.extend(
            shrink_corrupt_op(&v.op).into_iter().map(|op| MapCorruption { op, ..v.clone() }),
        );
        out
    }
}

/// Decoding arbitrarily mutated shard-map text never panics, and
/// whenever it succeeds the owner table is total: every shard within
/// `1..=MAX_SHARDS` resolves to a listed worker.
#[test]
fn prop_shardmap_decode_errors_or_yields_total_owner_table() {
    use h2opus_tlr::serve::shard::MAX_SHARDS;
    run_prop("shardmap_decode", REGRESSIONS, &MapCorruptionStrategy, |c| {
        let workers: Vec<String> = (0..c.n_workers).map(|i| format!("w{i}")).collect();
        let text = ShardMap::new(c.n_shards, workers).encode();
        let corrupt = String::from_utf8_lossy(&apply_corruption(text.as_bytes(), &c.op))
            .into_owned();
        no_panic("ShardMap::decode on corrupt text", || match ShardMap::decode(&corrupt) {
            Err(_) => {}
            Ok(m) => {
                assert!(m.n_shards() >= 1 && m.n_shards() <= MAX_SHARDS);
                assert!(!m.workers().is_empty());
                for s in 0..m.n_shards() {
                    let o = m.owner_of_shard(s);
                    assert!(
                        m.workers().iter().any(|w| w == o),
                        "shard {s} owned by unlisted worker {o:?}"
                    );
                }
            }
        })
    });
}

/// One step of a shard-map mutation sequence: add a worker from a
/// small name pool, or remove the worker at an index into the current
/// roster (reduced modulo its length).
#[derive(Clone, Debug)]
enum MapOp {
    Add(u8),
    Remove(u8),
}

#[derive(Clone, Debug)]
struct MapMutationSeq {
    n_shards: usize,
    init_workers: usize,
    ops: Vec<MapOp>,
}

struct MapMutationSeqStrategy;
impl Strategy for MapMutationSeqStrategy {
    type Value = MapMutationSeq;
    fn generate(&self, rng: &mut Rng) -> MapMutationSeq {
        let ops = (0..rng.below(9))
            .map(|_| {
                if rng.uniform() < 0.6 {
                    MapOp::Add(rng.below(6) as u8)
                } else {
                    MapOp::Remove(rng.below(8) as u8)
                }
            })
            .collect();
        MapMutationSeq { n_shards: 1 + rng.below(64), init_workers: 1 + rng.below(4), ops }
    }
    fn shrink(&self, v: &MapMutationSeq) -> Vec<MapMutationSeq> {
        let mut out = Vec::new();
        for i in 0..v.ops.len() {
            let mut ops = v.ops.clone();
            ops.remove(i);
            out.push(MapMutationSeq { ops, ..v.clone() });
        }
        if v.n_shards > 1 {
            out.push(MapMutationSeq { n_shards: v.n_shards / 2, ..v.clone() });
        }
        if v.init_workers > 1 {
            out.push(MapMutationSeq { init_workers: v.init_workers - 1, ..v.clone() });
        }
        out
    }
}

/// Arbitrary add/remove sequences keep the invariants the sharded
/// service relies on: the owner table stays total after every step,
/// rendezvous hashing moves only the shards it must (minimal
/// disruption: on add, every moved shard goes to the new worker and
/// nothing else changes; on remove, only the departed worker's shards
/// move), failed mutations leave the map untouched, and the text
/// encoding round-trips the exact map at every step.
#[test]
fn prop_shardmap_mutation_sequences_stay_total_and_minimal() {
    run_prop("shardmap_mutate", REGRESSIONS, &MapMutationSeqStrategy, |seq| {
        let workers: Vec<String> = (0..seq.init_workers).map(|i| format!("w{i}")).collect();
        let mut map = ShardMap::new(seq.n_shards, workers);
        for (step, op) in seq.ops.iter().enumerate() {
            let before = map.clone();
            match op {
                MapOp::Add(tag) => {
                    let name = format!("a{tag}");
                    match map.add_worker(name.clone()) {
                        Err(_) => {
                            // Duplicate id: must be a clean no-op.
                            if map != before {
                                return Err(format!("step {step}: failed add mutated map"));
                            }
                        }
                        Ok(moved) => {
                            for s in 0..map.n_shards() {
                                let (now, was) =
                                    (map.owner_of_shard(s), before.owner_of_shard(s));
                                if moved.contains(&s) {
                                    if now != name {
                                        return Err(format!(
                                            "step {step}: moved shard {s} went to {now}, \
                                             not the new worker"
                                        ));
                                    }
                                } else if now != was {
                                    return Err(format!(
                                        "step {step}: unmoved shard {s} changed owner \
                                         {was} -> {now}"
                                    ));
                                }
                            }
                        }
                    }
                }
                MapOp::Remove(idx) => {
                    let roster = before.workers().to_vec();
                    let name = roster[*idx as usize % roster.len()].clone();
                    match map.remove_worker(&name) {
                        Err(_) => {
                            // Only removing the last worker may fail.
                            if roster.len() != 1 || map != before {
                                return Err(format!(
                                    "step {step}: remove({name}) failed with {} workers",
                                    roster.len()
                                ));
                            }
                        }
                        Ok(moved) => {
                            if map.workers().iter().any(|w| *w == name) {
                                return Err(format!("step {step}: {name} still listed"));
                            }
                            for s in 0..map.n_shards() {
                                let (now, was) =
                                    (map.owner_of_shard(s), before.owner_of_shard(s));
                                if was == name {
                                    if !moved.contains(&s) {
                                        return Err(format!(
                                            "step {step}: shard {s} of removed worker \
                                             not reported moved"
                                        ));
                                    }
                                } else if now != was {
                                    return Err(format!(
                                        "step {step}: shard {s} moved off a surviving \
                                         worker {was} -> {now}"
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            // Totality and encode/decode round-trip after every step.
            for s in 0..map.n_shards() {
                let o = map.owner_of_shard(s).to_string();
                if !map.workers().iter().any(|w| *w == o) {
                    return Err(format!("step {step}: shard {s} owner {o} unlisted"));
                }
            }
            let rt = ShardMap::decode(&map.encode())
                .map_err(|e| format!("step {step}: re-decode failed: {e:?}"))?;
            if rt != map {
                return Err(format!("step {step}: encode/decode round-trip differs"));
            }
        }
        Ok(())
    });
}

// -------------------------------------------------------- CLI smoke

#[test]
fn serve_cli_smoke_fresh_process_reload() {
    let dir = temp_dir("cli");
    let store = dir.join("store");
    let run = |tag: &str| {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_serve"))
            .args([
                "--problem", "cov2d", "--n", "256", "--m", "64", "--eps", "1e-5", "--bs", "8",
                "--requests", "24", "--widths", "1,4", "--panel", "8", "--deadline-ms", "20",
                "--store", store.to_str().unwrap(),
            ])
            .output()
            .expect("serve binary must run");
        let text = format!(
            "{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(out.status.success(), "{tag}: {text}");
        text
    };
    let first = run("first");
    assert!(first.contains("store      : miss"), "{first}");
    assert!(first.contains("panel-width sweep"), "{first}");
    assert!(first.contains("requests/s"), "{first}");
    assert!(first.contains("serve done"), "{first}");
    // Second run is a fresh process: it must reuse the persisted factor.
    let second = run("second");
    assert!(second.contains("store      : cache hit"), "{second}");
    assert!(second.contains("serve done"), "{second}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_cli_smoke_sharded_mode() {
    let dir = temp_dir("cli_sharded");
    let store = dir.join("store");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_serve"))
        .args([
            "--problem", "cov2d", "--n", "256", "--m", "64", "--eps", "1e-5", "--bs", "8",
            "--requests", "32", "--widths", "1,4", "--panel", "4", "--deadline-ms", "20",
            "--shards", "2", "--keys", "3", "--store", store.to_str().unwrap(),
        ])
        .output()
        .expect("serve binary must run");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(out.status.success(), "{text}");
    assert!(text.contains("shard map"), "{text}");
    assert!(text.contains("sharded run"), "{text}");
    assert!(text.contains("shard w0"), "{text}");
    assert!(text.contains("shard w1"), "{text}");
    assert!(text.contains("rebalance"), "{text}");
    assert!(text.contains("serve done"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}
