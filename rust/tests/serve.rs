//! Integration tests for the `serve/` subsystem, in the seed-sweep
//! property style of `rust/tests/batch_plan.rs` (no proptest in the
//! vendored crate set; every assertion carries its seed):
//!
//! * serialization round trips are **bitwise**: random TLR matrices and
//!   real Cholesky/LDLᵀ factors survive save → load with every tile
//!   payload exactly equal;
//! * corruption (bit flips, truncation) is detected by the checksum;
//! * blocked multi-RHS solves match column-wise single solves to 1e-13;
//! * the [`SolveService`] coalesces ≥16 single-RHS requests into one
//!   blocked solve, loading the factor from a store written on disk —
//!   and the `serve` CLI proves the fresh-process path end to end.

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, ldlt, FactorOpts, Pivoting};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::serve::store::{
    decode_chol, decode_ldl, decode_tlr, encode_chol, encode_ldl, encode_tlr,
};
use h2opus_tlr::serve::{FactorStore, ServeError, ServeOpts, SolveService, StoredFactor};
use h2opus_tlr::solve::{
    chol_solve, chol_solve_multi, ldl_solve, ldl_solve_multi, pcg, pcg_multi, tlr_matvec,
    tlr_matvec_multi, tlr_trsm_lower, tlr_trsv_lower, TlrOp,
};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use h2opus_tlr::tlr::tile::{LowRank, Tile};
use h2opus_tlr::{Matrix, TlrMatrix};
use std::path::PathBuf;
use std::time::Duration;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("h2opus_serve_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Random symmetric TLR matrix with per-tile random ranks.
fn random_tlr(rng: &mut Rng, nb: usize) -> TlrMatrix {
    let sizes: Vec<usize> = (0..nb).map(|_| 3 + rng.below(10)).collect();
    let mut offsets = vec![0usize];
    for &s in &sizes {
        offsets.push(offsets.last().unwrap() + s);
    }
    let mut tiles = Vec::new();
    for i in 0..nb {
        for j in 0..=i {
            if i == j {
                let mut d = rng.normal_matrix(sizes[i], sizes[i]);
                d.symmetrize();
                tiles.push(Tile::Dense(d));
            } else {
                let k = rng.below(1 + sizes[i].min(sizes[j]));
                tiles.push(Tile::LowRank(LowRank {
                    u: rng.normal_matrix(sizes[i], k),
                    v: rng.normal_matrix(sizes[j], k),
                }));
            }
        }
    }
    TlrMatrix::from_tiles(offsets, tiles)
}

/// Small 2D covariance TLR matrix (the factor tests' recipe).
fn tlr_cov(n: usize, m: usize, eps: f64, seed: u64) -> TlrMatrix {
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, m);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    build_tlr(&cov, &c.offsets, &BuildOpts { eps, method: Compression::Svd, seed })
}

fn assert_tiles_bitwise(a: &TlrMatrix, b: &TlrMatrix, ctx: &str) {
    assert_eq!(a.offsets(), b.offsets(), "{ctx}: offsets");
    for i in 0..a.nb() {
        for j in 0..=i {
            match (a.tile(i, j), b.tile(i, j)) {
                (Tile::Dense(x), Tile::Dense(y)) => {
                    assert_eq!(x, y, "{ctx}: tile ({i},{j})");
                }
                (Tile::LowRank(x), Tile::LowRank(y)) => {
                    assert_eq!(x.u, y.u, "{ctx}: tile ({i},{j}) U");
                    assert_eq!(x.v, y.v, "{ctx}: tile ({i},{j}) V");
                }
                _ => panic!("{ctx}: tile ({i},{j}) kind changed"),
            }
        }
    }
}

fn assert_cols_close(panel: &Matrix, j: usize, single: &[f64], tol: f64, ctx: &str) {
    let scale = single.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1.0);
    let err: f64 = panel
        .col(j)
        .iter()
        .zip(single)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(err <= tol * scale, "{ctx}: col {j} err {err} > {tol} * {scale}");
}

// ------------------------------------------------ serialization props

#[test]
fn prop_tlr_roundtrip_bitwise() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(0x57E0 + seed);
        let nb = 1 + rng.below(6);
        let a = random_tlr(&mut rng, nb);
        let back = decode_tlr(&encode_tlr(&a)).unwrap();
        assert_tiles_bitwise(&a, &back, &format!("seed={seed}"));
    }
}

#[test]
fn prop_tlr_corruption_detected() {
    for seed in 0..12u64 {
        let mut rng = Rng::new(0xC0DE + seed);
        let nb = 2 + rng.below(4);
        let a = random_tlr(&mut rng, nb);
        let bytes = encode_tlr(&a);
        // Flip one bit somewhere past the fixed prefix.
        let mut corrupt = bytes.clone();
        let at = 40 + rng.below(corrupt.len() - 40);
        corrupt[at] ^= 1 << rng.below(8);
        assert!(decode_tlr(&corrupt).is_err(), "seed={seed}: flipped byte {at} undetected");
        // Truncations are rejected too.
        assert!(decode_tlr(&bytes[..bytes.len() - 1]).is_err(), "seed={seed}");
    }
}

#[test]
fn chol_factor_roundtrip_bitwise_with_pivoting() {
    let tlr = tlr_cov(200, 50, 1e-8, 21);
    let f = cholesky(
        tlr,
        &FactorOpts { eps: 1e-8, bs: 8, pivot: Pivoting::Frobenius, ..Default::default() },
    )
    .unwrap();
    let dir = temp_dir("chol_rt");
    let path = dir.join("f.bin");
    h2opus_tlr::serve::store::save_chol(&path, &f).unwrap();
    let back = h2opus_tlr::serve::store::load_chol(&path).unwrap();
    assert_tiles_bitwise(&f.l, &back.l, "chol");
    assert_eq!(f.stats.perm, back.stats.perm, "tile permutation");
    assert_eq!(f.scalar_perm(), back.scalar_perm(), "scalar permutation");
    // In-memory encode agrees with the file path.
    assert_eq!(encode_chol(&f), std::fs::read(&path).unwrap());
    let _ = decode_chol(&encode_chol(&f)).unwrap();
    // The loaded factor solves identically (bitwise inputs → 1e-13).
    let mut rng = Rng::new(22);
    let b: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
    let x0 = chol_solve(&f, &b);
    let x1 = chol_solve(&back, &b);
    let panel = Matrix::from_vec(200, 1, x1);
    assert_cols_close(&panel, 0, &x0, 1e-13, "loaded-factor solve");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ldl_factor_roundtrip_bitwise() {
    let tlr = tlr_cov(160, 40, 1e-8, 23);
    let f = ldlt(tlr, &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() }).unwrap();
    let bytes = encode_ldl(&f);
    let back = decode_ldl(&bytes).unwrap();
    assert_tiles_bitwise(&f.l, &back.l, "ldl");
    assert_eq!(f.d, back.d, "block diagonal");
}

// ----------------------------------------------------- blocked solves

#[test]
fn multi_solves_match_columnwise_singles() {
    let tlr = tlr_cov(256, 64, 1e-9, 24);
    let fc = cholesky(tlr.clone(), &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() })
        .unwrap();
    let fl = ldlt(tlr.clone(), &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() }).unwrap();
    let mut rng = Rng::new(25);
    for &r in &[1usize, 3, 16] {
        let b = rng.normal_matrix(256, r);
        let xc = chol_solve_multi(&fc, &b);
        let xl = ldl_solve_multi(&fl, &b);
        let ym = tlr_matvec_multi(&tlr, &b);
        let tm = tlr_trsm_lower(&fc.l, &b);
        for j in 0..r {
            let ctx = format!("r={r}");
            assert_cols_close(&xc, j, &chol_solve(&fc, b.col(j)), 1e-13, &format!("{ctx} chol"));
            assert_cols_close(&xl, j, &ldl_solve(&fl, b.col(j)), 1e-13, &format!("{ctx} ldl"));
            let mv = tlr_matvec(&tlr, b.col(j));
            assert_cols_close(&ym, j, &mv, 1e-13, &format!("{ctx} matvec"));
            let tv = tlr_trsv_lower(&fc.l, b.col(j));
            assert_cols_close(&tm, j, &tv, 1e-13, &format!("{ctx} trsm"));
        }
    }
}

#[test]
fn blocked_pcg_matches_columnwise_single() {
    let tlr = tlr_cov(200, 50, 1e-9, 26);
    let opts = FactorOpts { eps: 1e-3, bs: 8, shift: 1e-3, ..Default::default() };
    let f = cholesky(tlr.clone(), &opts).unwrap();
    let mut rng = Rng::new(27);
    let r = 4;
    let b = rng.normal_matrix(200, r);
    let op = TlrOp(&tlr);
    let minv_panel = |res: &Matrix| chol_solve_multi(&f, res);
    let multi = pcg_multi(&op, &minv_panel, &b, 1e-9, 200);
    for j in 0..r {
        let single = pcg(&op, &|res| chol_solve(&f, res), b.col(j), 1e-9, 200);
        assert!(multi.converged[j] && single.converged, "col {j}");
        // Iteration counts may differ by at most rounding at the tol
        // boundary (the exact per-column match is asserted
        // deterministically in solve::cg's unit tests).
        assert!(
            multi.iters[j].abs_diff(single.iters) <= 1,
            "col {j}: {} vs {} iterations",
            multi.iters[j],
            single.iters
        );
        let panel = &multi.x;
        assert_cols_close(panel, j, &single.x, 1e-6, "pcg");
    }
}

// ----------------------------------------------------------- service

#[test]
fn service_coalesces_16_requests_into_one_blocked_solve() {
    let n = 256;
    let tlr = tlr_cov(n, 64, 1e-9, 28);
    let f = cholesky(tlr, &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() }).unwrap();
    let dir = temp_dir("svc");
    let key = 0xFACADEu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f, "test factor").unwrap();
    // The service gets its own store handle: the factor crosses only
    // through the disk format.
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 16,
            flush_deadline: Duration::from_millis(2000),
            cache_capacity: 2,
        },
    );
    let mut rng = Rng::new(29);
    let rhss: Vec<Vec<f64>> =
        (0..16).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    let tickets: Vec<_> = rhss.iter().map(|b| service.submit(key, b.clone())).collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let resp = t.wait().unwrap();
        assert_eq!(resp.panel_width, 16, "request {i} not coalesced");
        let single = chol_solve(&f, &rhss[i]);
        let panel = Matrix::from_vec(n, 1, resp.x);
        assert_cols_close(&panel, 0, &single, 1e-13, &format!("request {i}"));
        assert!(resp.latency > Duration::ZERO);
    }
    let stats = service.stats();
    assert_eq!(stats.requests, 16);
    assert_eq!(stats.batches, 1, "16 requests must run as one blocked solve");
    assert_eq!(stats.max_panel, 16);
    assert!((stats.mean_panel_width() - 16.0).abs() < 1e-9);
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn service_reports_unknown_key_and_bad_rhs() {
    let n = 160;
    let tlr = tlr_cov(n, 40, 1e-8, 30);
    let f = ldlt(tlr, &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() }).unwrap();
    let dir = temp_dir("svc_err");
    let key = 0xBEEFu64;
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts { max_panel: 4, flush_deadline: Duration::from_millis(5), ..Default::default() },
    );
    // Unknown key: the store is empty.
    match service.submit(0xDEAD, vec![0.0; n]).wait() {
        Err(ServeError::UnknownFactor(k)) => assert_eq!(k, 0xDEAD),
        other => panic!("expected UnknownFactor, got {other:?}"),
    }
    // Register in memory (no disk write) and solve through the registry,
    // including a malformed RHS alongside a valid one.
    service.register(key, StoredFactor::Ldl(f));
    let bad = service.submit(key, vec![1.0; n + 3]);
    let good = service.submit(key, vec![1.0; n]);
    match bad.wait() {
        Err(ServeError::BadRhs { expected, got }) => {
            assert_eq!(expected, n);
            assert_eq!(got, n + 3);
        }
        other => panic!("expected BadRhs, got {other:?}"),
    }
    let resp = good.wait().unwrap();
    assert_eq!(resp.x.len(), n);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn factor_store_keys_and_missing() {
    let dir = temp_dir("store_keys");
    let store = FactorStore::open(&dir).unwrap();
    assert!(store.load(42).unwrap().is_none());
    assert!(!store.contains(42));
    let tlr = tlr_cov(128, 32, 1e-6, 31);
    let f = cholesky(tlr, &FactorOpts { eps: 1e-6, bs: 8, ..Default::default() }).unwrap();
    store.save_chol(7, &f, "seven").unwrap();
    store.save_chol(9, &f, "nine").unwrap();
    assert!(store.contains(7));
    assert_eq!(store.keys().unwrap(), vec![7, 9]);
    match store.load(7).unwrap() {
        Some(StoredFactor::Chol(back)) => assert_tiles_bitwise(&f.l, &back.l, "store"),
        other => panic!("expected Chol factor, got {:?}", other.map(|f| f.n())),
    }
    // A key holds exactly one factor: saving the other kind replaces it.
    let tlr2 = tlr_cov(128, 32, 1e-6, 31);
    let fl = ldlt(tlr2, &FactorOpts { eps: 1e-6, bs: 8, ..Default::default() }).unwrap();
    store.save_ldl(7, &fl, "seven-ldl").unwrap();
    match store.load(7).unwrap() {
        Some(StoredFactor::Ldl(back)) => assert_eq!(fl.d, back.d),
        _ => panic!("save_ldl must replace the chol factor under the same key"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------------- CLI smoke

#[test]
fn serve_cli_smoke_fresh_process_reload() {
    let dir = temp_dir("cli");
    let store = dir.join("store");
    let run = |tag: &str| {
        let out = std::process::Command::new(env!("CARGO_BIN_EXE_serve"))
            .args([
                "--problem", "cov2d", "--n", "256", "--m", "64", "--eps", "1e-5", "--bs", "8",
                "--requests", "24", "--widths", "1,4", "--panel", "8", "--deadline-ms", "20",
                "--store", store.to_str().unwrap(),
            ])
            .output()
            .expect("serve binary must run");
        let text = format!(
            "{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
        assert!(out.status.success(), "{tag}: {text}");
        text
    };
    let first = run("first");
    assert!(first.contains("store      : miss"), "{first}");
    assert!(first.contains("panel-width sweep"), "{first}");
    assert!(first.contains("requests/s"), "{first}");
    assert!(first.contains("serve done"), "{first}");
    // Second run is a fresh process: it must reuse the persisted factor.
    let second = run("second");
    assert!(second.contains("store      : cache hit"), "{second}");
    assert!(second.contains("serve done"), "{second}");
    let _ = std::fs::remove_dir_all(&dir);
}
