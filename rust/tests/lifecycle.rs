//! Integration tests for the factor lifecycle: generation-versioned
//! identity, hot swap under live load, and idle-generation GC — the
//! contract spelled out in `serve/mod.rs` §The factor-lifecycle
//! contract:
//!
//! * a ticket executes against exactly the generation it was admitted
//!   on, and width-1 replays of the same RHS against the same
//!   generation are **bitwise identical** — a swap landing mid-stream
//!   never perturbs pre-swap answers;
//! * zero tickets are lost across a swap: every submission resolves
//!   `Ok` with its pinned generation's solution;
//! * [`SolveService::collect_idle`] refuses to reap while queued work
//!   still pins a superseded generation, then reaps exactly the stale
//!   ids once the service drains;
//! * the sharded front-end routes on the base key only — swapping a
//!   new generation in never moves the key between workers;
//! * arbitrary submit/swap/collect interleaves (proptest, shrinking to
//!   a minimal op sequence) keep all of the above total.

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, CholFactor, FactorOpts};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::serve::{
    FactorId, FactorStore, ServeOpts, ShardedService, SolveService, StoredFactor,
};
use h2opus_tlr::solve::chol_solve;
use h2opus_tlr::testing::proptest::{run_prop_with, Config, Strategy};
use h2opus_tlr::tlr::chol_rank_k_update;
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use h2opus_tlr::TlrMatrix;
use std::path::PathBuf;
use std::time::Duration;

/// Pinned counterexample seeds, replayed before any fresh generation.
const REGRESSIONS: &str = include_str!("proptest-regressions/lifecycle.txt");

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("h2opus_lifecycle_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small 2D covariance TLR matrix (the factor tests' recipe).
fn tlr_cov(n: usize, m: usize, eps: f64, seed: u64) -> TlrMatrix {
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, m);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    build_tlr(&cov, &c.offsets, &BuildOpts { eps, method: Compression::Svd, seed })
}

/// Gen-0 factor plus a rank-2-updated successor of it (the gen-1
/// candidate a live refresh would hot-swap in).
fn factor_pair(n: usize, m: usize, eps: f64, seed: u64) -> (CholFactor, CholFactor) {
    let f0 = cholesky(tlr_cov(n, m, eps, seed), &FactorOpts { eps, bs: 8, ..Default::default() })
        .unwrap();
    let mut f1 = f0.clone();
    let mut rng = Rng::new(seed ^ 0x5A9);
    let mut w = rng.normal_matrix(n, 2);
    w.scale(0.05);
    chol_rank_k_update(&mut f1.l, &w, &FactorOpts { eps, bs: 8, ..Default::default() }).unwrap();
    (f0, f1)
}

fn assert_bitwise(a: &[f64], b: &[f64], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{ctx}: x[{i}] {x:e} != {y:e} (bitwise)");
    }
}

fn assert_close(x: &[f64], x_ref: &[f64], tol: f64, ctx: &str) {
    let scale = x_ref.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1.0);
    let err = x.iter().zip(x_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    assert!(err <= tol * scale, "{ctx}: err {err} > {tol} * {scale}");
}

/// The acceptance test: a swap lands while gen-0 tickets are in
/// flight. No ticket is lost, every response carries the generation it
/// was admitted on, width-1 replays against gen 0 are bitwise
/// identical across the swap, and the superseded generation is reaped
/// exactly once the stream drains.
#[test]
fn hot_swap_under_load_pins_generations_and_collects_idle() {
    let (n, m) = (192, 48);
    let (f0, f1) = factor_pair(n, m, 1e-9, 41);
    let dir = temp_dir("swap_load");
    let key = 0x11FEu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    // max_panel 1: every request is its own width-1 blocked solve, so a
    // replay of the same RHS against the same generation is bitwise
    // deterministic (no panel-composition nondeterminism).
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 1,
            flush_deadline: Duration::from_millis(2),
            cache_capacity: 2,
            ..Default::default()
        },
    );
    assert_eq!(service.current_generation(key), 0);
    let mut rng = Rng::new(43);
    let rhss: Vec<Vec<f64>> = (0..6).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
    // Round A: all six RHS served at generation 0.
    let round_a: Vec<Vec<f64>> = rhss
        .iter()
        .map(|b| {
            let r = service.submit(key, b.clone()).unwrap().wait().unwrap();
            assert_eq!(r.generation, 0, "round A must serve gen 0");
            r.x
        })
        .collect();
    for (i, x) in round_a.iter().enumerate() {
        assert_close(x, &chol_solve(&f0, &rhss[i]), 1e-12, &format!("round A rhs {i}"));
    }
    // Live round: three gen-0 replays go in flight, the swap lands,
    // three more submissions follow on the new generation.
    let pre: Vec<_> = rhss[..3].iter().map(|b| service.submit(key, b.clone()).unwrap()).collect();
    let id = service.swap(key, StoredFactor::Chol(f1.clone()));
    assert_eq!(id, FactorId { key, generation: 1 });
    assert_eq!(service.current_generation(key), 1);
    let post: Vec<_> = rhss[3..].iter().map(|b| service.submit(key, b.clone()).unwrap()).collect();
    for (i, t) in pre.into_iter().enumerate() {
        let r = t.wait().unwrap_or_else(|e| panic!("pre-swap ticket {i} lost: {e}"));
        assert_eq!(r.generation, 0, "pre-swap ticket {i} must stay pinned to gen 0");
        // Same RHS, same generation, width-1 panel: bitwise replay.
        assert_bitwise(&r.x, &round_a[i], &format!("pre-swap replay {i}"));
    }
    for (i, t) in post.into_iter().enumerate() {
        let r = t.wait().unwrap_or_else(|e| panic!("post-swap ticket {i} lost: {e}"));
        assert_eq!(r.generation, 1, "post-swap ticket {i} must serve gen 1");
        let x_ref = chol_solve(&f1, &rhss[3 + i]);
        assert_close(&r.x, &x_ref, 1e-12, &format!("post-swap rhs {i}"));
        // The update genuinely changed the operator: gen-1 answers
        // differ from gen-0 answers for the same RHS.
        assert!(
            r.x.iter().zip(&round_a[3 + i]).any(|(a, b)| a != b),
            "post-swap rhs {i}: gen 1 answer identical to gen 0"
        );
    }
    // Drained: GC must reap exactly the superseded generation (the
    // disk-resolved gen 0 in the factor LRU), and serving continues.
    let collected = service.collect_idle(key);
    assert!(
        collected.contains(&FactorId::base(key)),
        "gen 0 not collected: {collected:?}"
    );
    assert!(collected.iter().all(|c| c.key == key && c.generation < 1), "{collected:?}");
    let r = service.submit(key, rhss[0].clone()).unwrap().wait().unwrap();
    assert_eq!(r.generation, 1, "post-GC serving must stay on gen 1");
    assert!(service.collect_idle(key).is_empty(), "second collect must be a no-op");
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

/// GC refuses while queued tickets still pin the old generation, then
/// reaps once the queue drains.
#[test]
fn collect_idle_refuses_while_old_generation_pinned() {
    let (n, m) = (128, 32);
    let (f0, f1) = factor_pair(n, m, 1e-8, 47);
    let dir = temp_dir("gc_pin");
    let key = 0x6Cu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    // Wide panel + long deadline: the gen-0 submissions sit queued long
    // enough for the swap and the premature collect to land first.
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 8,
            flush_deadline: Duration::from_millis(300),
            ..Default::default()
        },
    );
    let mut rng = Rng::new(48);
    let tickets: Vec<_> = (0..3)
        .map(|_| service.submit(key, (0..n).map(|_| rng.normal()).collect()).unwrap())
        .collect();
    let id = service.swap(key, StoredFactor::Chol(f1));
    assert_eq!(id.generation, 1);
    assert!(
        service.collect_idle(key).is_empty(),
        "collect_idle must refuse while queued tickets pin gen 0"
    );
    for t in tickets {
        assert_eq!(t.wait().unwrap().generation, 0);
    }
    let collected = service.collect_idle(key);
    assert!(!collected.is_empty(), "drained gen 0 must be collectable");
    assert!(collected.iter().all(|c| c.generation < 1), "{collected:?}");
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Sharded front-end: generations never enter routing — the key's
/// owner is identical before and after a swap — and the owning worker
/// enforces the same pinning + GC contract.
#[test]
fn sharded_swap_keeps_owner_and_pins_generations() {
    let (n, m) = (128, 32);
    let (f0, f1) = factor_pair(n, m, 1e-8, 53);
    let dir = temp_dir("shard_swap");
    let key = 0x5AFEu64;
    let store = FactorStore::open(&dir).unwrap();
    store.save_chol(key, &f0, "gen 0").unwrap();
    let service = ShardedService::start(
        &store,
        ServeOpts {
            max_panel: 4,
            flush_deadline: Duration::from_millis(2),
            ..Default::default()
        },
        2,
        16,
    )
    .unwrap();
    let owner_before = service.map().owner_of(key).to_string();
    let mut rng = Rng::new(54);
    let mk = |rng: &mut Rng| -> Vec<f64> { (0..n).map(|_| rng.normal()).collect() };
    let pre: Vec<_> = (0..2).map(|_| service.submit(key, mk(&mut rng)).unwrap()).collect();
    let id = service.swap(key, StoredFactor::Chol(f1));
    assert_eq!(id, FactorId { key, generation: 1 });
    assert_eq!(service.current_generation(key), 1);
    assert_eq!(
        service.map().owner_of(key),
        owner_before,
        "swap must not move the key between workers"
    );
    let post: Vec<_> = (0..2).map(|_| service.submit(key, mk(&mut rng)).unwrap()).collect();
    for t in pre {
        assert_eq!(t.wait().unwrap().generation, 0);
    }
    for t in post {
        assert_eq!(t.wait().unwrap().generation, 1);
    }
    let collected = service.collect_idle(key);
    assert!(!collected.is_empty(), "superseded generation not collected on the owner");
    assert!(collected.iter().all(|c| c.key == key && c.generation < 1), "{collected:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------- proptest interleaves

/// One step of a lifecycle interleave.
#[derive(Clone, Debug)]
enum LifeOp {
    /// Submit one RHS derived from the seed byte.
    Submit(u8),
    /// Hot-swap the next generation in.
    Swap,
    /// Attempt idle-generation GC.
    Collect,
}

/// A whole interleave, shrinking by dropping ops (a failing sequence
/// shrinks toward the minimal submit/swap/collect pattern).
#[derive(Clone, Debug)]
struct LifeSeq {
    ops: Vec<LifeOp>,
}

struct LifeSeqStrategy;

impl Strategy for LifeSeqStrategy {
    type Value = LifeSeq;

    fn generate(&self, rng: &mut Rng) -> LifeSeq {
        let len = 1 + rng.below(10);
        let ops = (0..len)
            .map(|_| match rng.below(4) {
                0 => LifeOp::Swap,
                1 => LifeOp::Collect,
                _ => LifeOp::Submit(rng.below(256) as u8),
            })
            .collect();
        LifeSeq { ops }
    }

    fn shrink(&self, value: &LifeSeq) -> Vec<LifeSeq> {
        let mut out = Vec::new();
        if value.ops.len() > 1 {
            out.push(LifeSeq { ops: value.ops[..value.ops.len() / 2].to_vec() });
            for i in 0..value.ops.len() {
                let mut ops = value.ops.clone();
                ops.remove(i);
                out.push(LifeSeq { ops });
            }
        }
        out
    }
}

/// Arbitrary submit/swap/collect interleaves stay total: every ticket
/// resolves `Ok` on the generation it was admitted on, its solution
/// matches that generation's factor, and GC only ever returns
/// superseded ids. Generation g serves `variants[g % 2]`, so the model
/// knows the right answer at any depth of swapping.
#[test]
fn prop_lifecycle_interleaves_are_total_and_generation_pinned() {
    let (n, m) = (96, 24);
    let (f0, f1) = factor_pair(n, m, 1e-8, 59);
    let variants = [f0.clone(), f1.clone()];
    let dir = temp_dir("prop_life");
    let key = 0x91Eu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let cfg = Config { cases: 12, max_shrink_steps: 120 };
    run_prop_with(cfg, "lifecycle_interleaves", REGRESSIONS, &LifeSeqStrategy, |seq| {
        let service = SolveService::start(
            FactorStore::open(&dir).unwrap(),
            ServeOpts {
                max_panel: 4,
                flush_deadline: Duration::from_millis(2),
                cache_capacity: 2,
                ..Default::default()
            },
        );
        let mut expected_gen = 0u32;
        let mut in_flight = Vec::new();
        for (step, op) in seq.ops.iter().enumerate() {
            match op {
                LifeOp::Submit(seed) => {
                    let mut rng = Rng::new(*seed as u64 + 1);
                    let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let t = service
                        .submit(key, rhs.clone())
                        .map_err(|e| format!("step {step}: submit rejected: {e}"))?;
                    in_flight.push((step, expected_gen, rhs, t));
                }
                LifeOp::Swap => {
                    let next = variants[(expected_gen as usize + 1) % 2].clone();
                    let id = service.swap(key, StoredFactor::Chol(next));
                    expected_gen += 1;
                    if id != (FactorId { key, generation: expected_gen }) {
                        return Err(format!("step {step}: swap returned {id}"));
                    }
                }
                LifeOp::Collect => {
                    for c in service.collect_idle(key) {
                        if c.key != key || c.generation >= expected_gen {
                            return Err(format!("step {step}: GC reaped live id {c}"));
                        }
                    }
                }
            }
        }
        for (step, gen, rhs, t) in in_flight {
            let r = t.wait().map_err(|e| format!("ticket from step {step} lost: {e}"))?;
            if r.generation != gen {
                return Err(format!(
                    "ticket from step {step}: admitted on gen {gen}, served by {}",
                    r.generation
                ));
            }
            let x_ref = chol_solve(&variants[gen as usize % 2], &rhs);
            let scale = x_ref.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1.0);
            let err =
                r.x.iter().zip(&x_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            if err > 1e-10 * scale {
                return Err(format!("ticket from step {step}: err {err} vs gen {gen}"));
            }
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}
