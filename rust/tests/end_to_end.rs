//! End-to-end integration: generator → KD-tree ordering → TLR build →
//! factorization → solve, on both evaluation problems of the paper
//! (spatial-statistics covariance and 3D fractional diffusion).

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::fracdiff::FracDiffusion;
use h2opus_tlr::apps::geometry::{grid, random_ball};
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::apps::matgen::MatGen;
use h2opus_tlr::factor::{cholesky, ldlt, FactorOpts, Pivoting};
use h2opus_tlr::linalg::norms::l2;
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::solve::{chol_solve, factorization_error, ldl_solve, pcg, tlr_matvec, TlrOp};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};

#[test]
fn covariance_2d_factor_solve_roundtrip() {
    let n = 400;
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, 64);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let opts = BuildOpts { eps: 1e-8, method: Compression::Ara { bs: 8 }, seed: 1 };
    let tlr = build_tlr(&cov, &c.offsets, &opts);
    let dense = cov.dense();

    let f = cholesky(tlr.clone(), &FactorOpts { eps: 1e-8, bs: 8, ..Default::default() }).unwrap();

    // Solve A x = b through the factor and check against the dense matvec.
    let mut rng = Rng::new(2);
    let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let b = dense.matvec(&x_true);
    let x = chol_solve(&f, &b);
    let err = x.iter().zip(&x_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    assert!(err < 1e-4, "solve error {err}");

    // Power-iteration estimate of ‖A − L Lᵀ‖₂ (the paper's verification).
    let e2 = factorization_error(&tlr, &f, 30, 3);
    assert!(e2 < 1e-5, "‖A − LLᵀ‖₂ ≈ {e2}");
}

#[test]
fn covariance_3d_ball_with_pivoting() {
    let n = 384;
    let pts = random_ball(n, 3, 7);
    let c = kdtree_order(&pts, 64);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let opts = BuildOpts { eps: 1e-7, method: Compression::Ara { bs: 8 }, seed: 4 };
    let tlr = build_tlr(&cov, &c.offsets, &opts);
    let dense = cov.dense();

    let f = cholesky(
        tlr,
        &FactorOpts { eps: 1e-7, bs: 8, pivot: Pivoting::Frobenius, ..Default::default() },
    )
    .unwrap();

    // P A Pᵀ = L Lᵀ: verify through the scalar permutation.
    let perm = f.scalar_perm();
    let ld = f.l.to_dense_lower();
    let mut rng = Rng::new(5);
    // Spot-check reconstruction entries (full O(n³) reconstruction is fine
    // at this size, but entrywise keeps the test sharp about the perm).
    for _ in 0..200 {
        let i = rng.below(n);
        let j = rng.below(n);
        let mut lij = 0.0;
        for q in 0..n {
            lij += ld[(i, q)] * ld[(j, q)];
        }
        let aij = dense[(perm[i], perm[j])];
        assert!((lij - aij).abs() < 1e-4, "({i},{j}): {lij} vs {aij}");
    }
}

#[test]
fn fracdiff_preconditioned_cg_converges() {
    // The paper's §6.2 scenario: ill-conditioned fractional-diffusion
    // system, preconditioned with the TLR Cholesky of A + εI.
    let n = 512;
    let pts = grid(n, 3);
    let c = kdtree_order(&pts, 64);
    let fd = FracDiffusion::new(pts.permuted(&c.perm), 0.5, 1.0);
    let opts = BuildOpts { eps: 1e-4, method: Compression::Ara { bs: 8 }, seed: 8 };
    let tlr = build_tlr(&fd, &c.offsets, &opts);

    let eps = 1e-4;
    let f = cholesky(
        tlr.clone(),
        &FactorOpts { eps, bs: 8, shift: eps, ..Default::default() },
    )
    .unwrap();

    let mut rng = Rng::new(9);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let pre = pcg(&TlrOp(&tlr), &|r| chol_solve(&f, r), &b, 1e-8, 300);
    let resid = pre.history.last().unwrap();
    assert!(pre.converged, "PCG stalled: {} iters, residual {resid}", pre.iters);

    let plain = pcg(&TlrOp(&tlr), &|r| r.to_vec(), &b, 1e-8, 300);
    assert!(
        !plain.converged || pre.iters < plain.iters,
        "preconditioner should help: pre={} plain={}",
        pre.iters,
        plain.iters
    );

    // Check the solution against the TLR operator itself.
    let ax = tlr_matvec(&tlr, &pre.x);
    let rnorm = l2(&ax.iter().zip(&b).map(|(a, b)| a - b).collect::<Vec<_>>()) / l2(&b);
    assert!(rnorm < 1e-7, "residual {rnorm}");
}

#[test]
fn ldlt_solve_roundtrip() {
    let n = 256;
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, 64);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let opts = BuildOpts { eps: 1e-9, method: Compression::Svd, seed: 11 };
    let tlr = build_tlr(&cov, &c.offsets, &opts);
    let dense = cov.dense();
    let f = ldlt(tlr, &FactorOpts { eps: 1e-9, bs: 8, ..Default::default() }).unwrap();
    let mut rng = Rng::new(12);
    let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let b = dense.matvec(&x_true);
    let x = ldl_solve(&f, &b);
    let err = x.iter().zip(&x_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    assert!(err < 1e-5, "ldl solve error {err}");
}

#[test]
fn schur_compensation_enables_loose_epsilon() {
    // At very loose ε the plain factorization of an ill-conditioned matrix
    // can break down; Schur compensation (§5.1.1) must keep it SPD.
    let n = 512;
    let pts = grid(n, 3);
    let c = kdtree_order(&pts, 64);
    let fd = FracDiffusion::new(pts.permuted(&c.perm), 0.5, 1.0);
    let opts = BuildOpts { eps: 1e-2, method: Compression::Ara { bs: 8 }, seed: 13 };
    let tlr = build_tlr(&fd, &c.offsets, &opts);
    let comp = cholesky(
        tlr.clone(),
        &FactorOpts { eps: 1e-2, bs: 8, schur_comp: true, ..Default::default() },
    );
    assert!(comp.is_ok(), "compensated factorization must not break down");
    // And it should still be a usable preconditioner.
    let f = comp.unwrap();
    let mut rng = Rng::new(14);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let r = pcg(&TlrOp(&tlr), &|r| chol_solve(&f, r), &b, 1e-6, 300);
    assert!(r.converged, "compensated preconditioner failed: {} iters", r.iters);
}
