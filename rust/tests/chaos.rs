//! Chaos suite: the resilience contract (`serve/mod.rs`
//! §resilience-contract) under the deterministic fault injector
//! ([`h2opus_tlr::testing::faults`]).
//!
//! Every test here installs a *process-global* fault plan, which is why
//! this suite is its own test binary (`Cargo.toml` pins it out of
//! `autotests`): injected faults must never leak into the lib unit
//! tests running in parallel processes. Within this binary, tests
//! serialize on `TEST_LOCK`.
//!
//! What is pinned:
//!
//! * checksum corruption → typed `CorruptFactor`, frame quarantined
//!   (`*.quarantine`), service keeps serving after a re-save;
//! * transient store I/O → bounded retry to success, and typed
//!   `Store` error on budget exhaustion with the frame left intact;
//! * post-validation truncation → typed format error at map time;
//! * a panel panic fails only that panel's tickets, typed
//!   `WorkerPanicked`, and the worker keeps serving;
//! * queue-wait deadlines expire overdue requests with a typed
//!   `DeadlineExceeded` while in-execution work still completes;
//! * overload with `degraded_serving` admits on the previous
//!   generation, response flagged `degraded`;
//! * the sharded front-end forwards all of the above unchanged;
//! * proptest over seeded fault schedules interleaved with
//!   submit/swap/collect: no ticket is ever lost, an `Ok` answer is
//!   always the *correct* answer for its pinned generation, stats stay
//!   monotone, and a fault-free replay is bitwise deterministic.

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, CholFactor, FactorOpts};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::obs::{self, ResilienceClass};
use h2opus_tlr::serve::{
    FactorId, FactorStore, ServeError, ServeOpts, ShardedService, SolveService, StoreError,
    StoredFactor,
};
use h2opus_tlr::solve::chol_solve;
use h2opus_tlr::testing::faults::{self, FaultKind, FaultPlan, FaultSite, Trigger};
use h2opus_tlr::testing::proptest::{run_prop_with, Config, Strategy};
use h2opus_tlr::tlr::chol_rank_k_update;
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use h2opus_tlr::TlrMatrix;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

/// Pinned counterexample seeds, replayed before any fresh generation.
const REGRESSIONS: &str = include_str!("proptest-regressions/chaos.txt");

/// The fault injector is process-global; every test that installs a
/// plan holds this for its whole body (poison-tolerant: a failing test
/// must not cascade into the rest of the suite).
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("h2opus_chaos_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Small 2D covariance TLR matrix (the factor tests' recipe).
fn tlr_cov(n: usize, m: usize, eps: f64, seed: u64) -> TlrMatrix {
    let pts = grid(n, 2);
    let c = kdtree_order(&pts, m);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    build_tlr(&cov, &c.offsets, &BuildOpts { eps, method: Compression::Svd, seed })
}

fn factor(n: usize, m: usize, eps: f64, seed: u64) -> CholFactor {
    cholesky(tlr_cov(n, m, eps, seed), &FactorOpts { eps, bs: 8, ..Default::default() }).unwrap()
}

/// Gen-0 factor plus a rank-2-updated successor (the gen-1 candidate).
fn factor_pair(n: usize, m: usize, eps: f64, seed: u64) -> (CholFactor, CholFactor) {
    let f0 = factor(n, m, eps, seed);
    let mut f1 = f0.clone();
    let mut rng = Rng::new(seed ^ 0x5A9);
    let mut w = rng.normal_matrix(n, 2);
    w.scale(0.05);
    chol_rank_k_update(&mut f1.l, &w, &FactorOpts { eps, bs: 8, ..Default::default() }).unwrap();
    (f0, f1)
}

fn rhs_for(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn quick_opts() -> ServeOpts {
    ServeOpts {
        max_panel: 1,
        flush_deadline: Duration::from_millis(1),
        cache_capacity: 2,
        ..Default::default()
    }
}

/// Max-norm closeness against a reference solve (service panels and
/// direct solves agree to rounding, not bitwise).
fn assert_close(x: &[f64], x_ref: &[f64], tol: f64, ctx: &str) {
    let scale = x_ref.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1.0);
    let err = x.iter().zip(x_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    assert!(err <= tol * scale, "{ctx}: err {err} > {tol} * {scale}");
}

// ------------------------------------------------ corruption handling

/// A frame that fails its checksum comes back as a typed
/// `CorruptFactor`, the file moves aside as `*.quarantine` (invisible
/// to later loads), and the service serves again after a re-save.
#[test]
fn checksum_corruption_quarantines_and_keeps_serving() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 61);
    let dir = temp_dir("corrupt");
    let key = 0xC0AAu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let service = SolveService::start(FactorStore::open(&dir).unwrap(), quick_opts());
    let before = obs::resilience_counts();
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::FrameChecksum,
        FaultKind::Corrupt,
        Trigger::Rate(1000),
    ));
    let verdict = service.submit(key, rhs_for(n, 2)).unwrap().wait();
    faults::clear();
    match verdict {
        Err(ServeError::CorruptFactor { key: k, detail }) => {
            assert_eq!(k, key);
            assert!(detail.contains("quarantined"), "detail should name the quarantine: {detail}");
        }
        other => panic!("expected CorruptFactor, got {other:?}"),
    }
    let after = obs::resilience_counts();
    assert!(
        after[ResilienceClass::Quarantined as usize] > before[ResilienceClass::Quarantined as usize],
        "quarantine must be counted"
    );
    let key_dir = dir.join(format!("{key:016x}"));
    let names: Vec<String> = std::fs::read_dir(&key_dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert!(
        names.iter().any(|f| f.ends_with(".quarantine")),
        "frame not quarantined: {names:?}"
    );
    assert!(!names.iter().any(|f| f == "chol.bin"), "original frame must move: {names:?}");
    // Quarantined frames are invisible: the key now looks unknown.
    match service.submit(key, rhs_for(n, 3)).unwrap().wait() {
        Err(ServeError::UnknownFactor(k)) => assert_eq!(k, key),
        other => panic!("expected UnknownFactor after quarantine, got {other:?}"),
    }
    // A re-save heals the key and the same worker serves it.
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0 again").unwrap();
    let r = service.submit(key, rhs_for(n, 4)).unwrap().wait().unwrap();
    assert_eq!(r.generation, 0);
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Post-validation truncation is re-checked at map time: the mapped
/// loader surfaces a typed format error instead of serving a view of a
/// file that shrank after its header said otherwise.
#[test]
fn mapped_truncation_is_caught_at_map_time() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 67);
    let dir = temp_dir("truncate");
    let key = 0x7514u64;
    let store = FactorStore::open(&dir).unwrap();
    store.save_chol(key, &f0, "gen 0").unwrap();
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::MapTruncation,
        FaultKind::Truncate,
        Trigger::Rate(1000),
    ));
    let verdict = store.load_mapped(key);
    faults::clear();
    match verdict {
        Err(StoreError::Format(msg)) => {
            assert!(msg.contains("truncated after validation"), "unexpected message: {msg}");
        }
        Err(e) => panic!("expected a truncation format error, got: {e}"),
        Ok(_) => panic!("a frame reported truncated must not load"),
    }
    // The fault was injected, not real: with the plan cleared the same
    // frame maps fine.
    assert!(store.load_mapped(key).unwrap().is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- retry discipline

/// A transient I/O error on the first read is retried to success; the
/// caller only ever sees `Ok`.
#[test]
fn transient_io_error_is_retried_to_success() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 71);
    let dir = temp_dir("retry_ok");
    let key = 0x2E72u64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let service = SolveService::start(FactorStore::open(&dir).unwrap(), quick_opts());
    let before = obs::resilience_counts();
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::StoreRead,
        FaultKind::IoError,
        Trigger::At(vec![0]),
    ));
    let r = service.submit(key, rhs_for(n, 5)).unwrap().wait();
    faults::clear();
    let resp = r.expect("one transient I/O error must be absorbed by retry");
    assert_eq!(resp.generation, 0);
    let after = obs::resilience_counts();
    let class = ResilienceClass::RetryAttempt as usize;
    assert!(after[class] > before[class], "the retry must be counted");
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Permanent I/O failure exhausts the retry budget and surfaces a
/// typed `Store` error; the frame is untouched (I/O errors never
/// quarantine) so recovery is immediate once the fault clears.
#[test]
fn retry_budget_exhaustion_is_typed_and_leaves_the_frame_intact() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 73);
    let dir = temp_dir("retry_exhaust");
    let key = 0xE4A5u64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let service = SolveService::start(FactorStore::open(&dir).unwrap(), quick_opts());
    let before = obs::resilience_counts();
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::StoreRead,
        FaultKind::IoError,
        Trigger::Rate(1000),
    ));
    let verdict = service.submit(key, rhs_for(n, 6)).unwrap().wait();
    faults::clear();
    match verdict {
        Err(ServeError::Store(msg)) => {
            assert!(msg.contains("retries"), "exhaustion should say so: {msg}");
        }
        other => panic!("expected Store after retry exhaustion, got {other:?}"),
    }
    let after = obs::resilience_counts();
    assert!(
        after[ResilienceClass::RetryExhausted as usize]
            > before[ResilienceClass::RetryExhausted as usize],
        "exhaustion must be counted"
    );
    // No quarantine, no corruption: the next request just works.
    let r = service.submit(key, rhs_for(n, 7)).unwrap().wait().unwrap();
    assert_eq!(r.generation, 0);
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ panic + deadline

/// A panicking panel solve fails that panel's tickets with a typed
/// `WorkerPanicked` — and the worker thread survives to serve the next
/// request.
#[test]
fn worker_panic_is_isolated_to_one_panel() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 79);
    let dir = temp_dir("panic");
    let key = 0x9A1Cu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let service = SolveService::start(FactorStore::open(&dir).unwrap(), quick_opts());
    let before = obs::resilience_counts();
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::PanelExec,
        FaultKind::Panic,
        Trigger::At(vec![0]),
    ));
    let verdict = service.submit(key, rhs_for(n, 8)).unwrap().wait();
    match verdict {
        Err(ServeError::WorkerPanicked { key: k, what }) => {
            assert_eq!(k, key);
            assert!(what.contains("injected"), "panic payload should surface: {what}");
        }
        other => panic!("expected WorkerPanicked, got {other:?}"),
    }
    // Same worker, next panel: alive and correct.
    let rhs = rhs_for(n, 9);
    let r = service.submit(key, rhs.clone()).unwrap().wait();
    faults::clear();
    let resp = r.expect("the worker must survive an isolated panic");
    assert_close(&resp.x, &chol_solve(&f0, &rhs), 1e-10, "post-panic solve");
    let after = obs::resilience_counts();
    assert!(
        after[ResilienceClass::WorkerPanic as usize] > before[ResilienceClass::WorkerPanic as usize],
        "the isolated panic must be counted"
    );
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

/// With a request deadline set, requests stuck in the queue behind a
/// stalled panel expire with a typed `DeadlineExceeded` carrying the
/// measured wait; the in-flight request itself still completes.
#[test]
fn overdue_queued_requests_expire_typed() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 83);
    let dir = temp_dir("deadline");
    let key = 0xDEADu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            request_deadline: Some(Duration::from_millis(30)),
            ..quick_opts()
        },
    );
    let before = obs::resilience_counts();
    // The first panel stalls 150 ms; everything queued behind it goes
    // past the 30 ms deadline and must be expired, not served late.
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::ExecDelay,
        FaultKind::Delay { ms: 150 },
        Trigger::At(vec![0]),
    ));
    let t1 = service.submit(key, rhs_for(n, 10)).unwrap();
    // Give the worker time to take t1 into execution before queueing.
    std::thread::sleep(Duration::from_millis(40));
    let t2 = service.submit(key, rhs_for(n, 11)).unwrap();
    let t3 = service.submit(key, rhs_for(n, 12)).unwrap();
    let r1 = t1.wait();
    let (r2, r3) = (t2.wait(), t3.wait());
    faults::clear();
    r1.expect("the stalled request itself is executing, not overdue in queue");
    for (i, r) in [(2, r2), (3, r3)] {
        match r {
            Err(ServeError::DeadlineExceeded { key: k, waited }) => {
                assert_eq!(k, key);
                assert!(waited >= Duration::from_millis(30), "t{i} waited {waited:?}");
            }
            other => panic!("t{i}: expected DeadlineExceeded, got {other:?}"),
        }
    }
    let after = obs::resilience_counts();
    assert!(
        after[ResilienceClass::DeadlineExpired as usize]
            >= before[ResilienceClass::DeadlineExpired as usize] + 2,
        "both expiries must be counted"
    );
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------- graceful degradation

/// When the queue is full and a previous generation is still
/// registered, `degraded_serving` admits the request on that
/// generation — response flagged `degraded` — instead of rejecting.
#[test]
fn overload_degrades_to_previous_generation_before_rejecting() {
    let _g = lock();
    let (n, m) = (96, 24);
    let (f0, f1) = factor_pair(n, m, 1e-8, 89);
    let dir = temp_dir("degrade");
    let key = 0xDE62u64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_backlog: 1,
            degraded_serving: true,
            ..quick_opts()
        },
    );
    let before = obs::resilience_counts();
    // Keep gen 0 registered (the swap alone would leave it on disk
    // only; the degradation ladder requires a *registered* previous
    // generation so a degraded admit can never block on the store).
    service.register(key, StoredFactor::Chol(f0.clone()));
    let id = service.swap(key, StoredFactor::Chol(f1.clone()));
    assert_eq!(id.generation, 1);
    // Stall the worker so the queue genuinely fills.
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::ExecDelay,
        FaultKind::Delay { ms: 150 },
        Trigger::At(vec![0]),
    ));
    let t1 = service.submit(key, rhs_for(n, 13)).unwrap();
    std::thread::sleep(Duration::from_millis(40));
    // t2 fills the single-slot backlog; t3 hits Overloaded and must be
    // admitted degraded on gen 0; t4 exceeds even the degraded bound.
    let t2 = service.submit(key, rhs_for(n, 14)).unwrap();
    let rhs3 = rhs_for(n, 15);
    let t3 = service.submit(key, rhs3.clone()).unwrap();
    let t4 = service.submit(key, rhs_for(n, 16));
    let r1 = t1.wait().expect("stalled request completes");
    let r2 = t2.wait().expect("queued request completes");
    let r3 = t3.wait().expect("degraded request completes");
    faults::clear();
    assert_eq!(r1.generation, 1);
    assert!(!r1.degraded);
    assert_eq!(r2.generation, 1);
    assert!(!r2.degraded);
    assert_eq!(r3.generation, 0, "degraded admit must pin the previous generation");
    assert!(r3.degraded, "the response must carry the degraded flag");
    assert_close(&r3.x, &chol_solve(&f0, &rhs3), 1e-10, "degraded answer is gen 0's answer");
    match t4 {
        Err(ServeError::Overloaded { .. }) => {}
        Ok(_) => panic!("t4 must be rejected: the degraded bound is 2x backlog"),
        Err(e) => panic!("t4: expected Overloaded, got {e}"),
    }
    let after = obs::resilience_counts();
    assert!(
        after[ResilienceClass::Degraded as usize] > before[ResilienceClass::Degraded as usize],
        "degraded admission must be counted"
    );
    drop(service);
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ sharded surface

/// The sharded front-end forwards the typed error surface unchanged
/// and keeps serving after a panic on the owning worker; leftover
/// `*.tmp.*` files sweep through the same facade.
#[test]
fn sharded_service_forwards_typed_errors_and_sweeps_tmp() {
    let _g = lock();
    let (n, m) = (96, 24);
    let f0 = factor(n, m, 1e-8, 97);
    let dir = temp_dir("shard");
    let key = 0x54A2u64;
    let store = FactorStore::open(&dir).unwrap();
    store.save_chol(key, &f0, "gen 0").unwrap();
    // A stale tmp file from a crashed writer: invisible to loads,
    // removed by the sweep.
    std::fs::write(dir.join(format!("{key:016x}")).join("chol.tmp.999.0"), b"junk").unwrap();
    let service = ShardedService::start(&store, quick_opts(), 2, 16).unwrap();
    faults::install(FaultPlan::seeded(1).with(
        FaultSite::PanelExec,
        FaultKind::Panic,
        Trigger::At(vec![0]),
    ));
    match service.submit(key, rhs_for(n, 17)).unwrap().wait() {
        Err(ServeError::WorkerPanicked { key: k, .. }) => assert_eq!(k, key),
        other => panic!("typed panic must cross the routing layer, got {other:?}"),
    }
    let r = service.submit(key, rhs_for(n, 18)).unwrap().wait();
    faults::clear();
    r.expect("the owning worker must survive the isolated panic");
    assert_eq!(service.sweep_store_tmp(key).unwrap(), 1, "one stale tmp file swept");
    assert_eq!(service.sweep_store_tmp(key).unwrap(), 0, "sweep is idempotent");
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------- proptest fault schedules

/// One step of a chaos interleave.
#[derive(Clone, Debug)]
enum ChaosOp {
    /// Submit one RHS derived from the seed byte.
    Submit(u8),
    /// Hot-swap the next generation in.
    Swap,
    /// Attempt idle-generation GC.
    Collect,
}

/// A seeded fault schedule (rates per non-destructive site) plus an
/// op interleave. Shrinks by dropping ops and by zeroing rates, so a
/// failure reduces toward the minimal schedule that still breaks.
#[derive(Clone, Debug)]
struct ChaosCase {
    seed: u64,
    /// `store_read` transient-I/O permille.
    io_rate: u16,
    /// `panel_exec` panic permille.
    panic_rate: u16,
    /// `exec_delay` 1 ms stall permille.
    delay_rate: u16,
    ops: Vec<ChaosOp>,
}

fn case_plan(c: &ChaosCase) -> FaultPlan {
    // Corruption/truncation sites stay out of the schedule: they
    // quarantine real frame files, and the property reuses one store
    // directory across cases. Their handling is pinned by the
    // dedicated tests above.
    let mut p = FaultPlan::seeded(c.seed);
    if c.io_rate > 0 {
        p = p.with(FaultSite::StoreRead, FaultKind::IoError, Trigger::Rate(c.io_rate));
    }
    if c.panic_rate > 0 {
        p = p.with(FaultSite::PanelExec, FaultKind::Panic, Trigger::Rate(c.panic_rate));
    }
    if c.delay_rate > 0 {
        p = p.with(FaultSite::ExecDelay, FaultKind::Delay { ms: 1 }, Trigger::Rate(c.delay_rate));
    }
    p
}

struct ChaosCaseStrategy;

impl Strategy for ChaosCaseStrategy {
    type Value = ChaosCase;

    fn generate(&self, rng: &mut Rng) -> ChaosCase {
        let len = 1 + rng.below(8);
        let ops = (0..len)
            .map(|_| match rng.below(4) {
                0 => ChaosOp::Swap,
                1 => ChaosOp::Collect,
                _ => ChaosOp::Submit(rng.below(256) as u8),
            })
            .collect();
        ChaosCase {
            seed: rng.below(1 << 30) as u64,
            io_rate: rng.below(300) as u16,
            panic_rate: rng.below(250) as u16,
            delay_rate: rng.below(300) as u16,
            ops,
        }
    }

    fn shrink(&self, v: &ChaosCase) -> Vec<ChaosCase> {
        let mut out = Vec::new();
        if v.ops.len() > 1 {
            out.push(ChaosCase { ops: v.ops[..v.ops.len() / 2].to_vec(), ..v.clone() });
            for i in 0..v.ops.len() {
                let mut ops = v.ops.clone();
                ops.remove(i);
                out.push(ChaosCase { ops, ..v.clone() });
            }
        }
        if v.io_rate > 0 {
            out.push(ChaosCase { io_rate: 0, ..v.clone() });
        }
        if v.panic_rate > 0 {
            out.push(ChaosCase { panic_rate: 0, ..v.clone() });
        }
        if v.delay_rate > 0 {
            out.push(ChaosCase { delay_rate: 0, ..v.clone() });
        }
        out
    }
}

/// Seeded fault schedules interleaved with submit/swap/collect: every
/// ticket resolves (Ok or typed error — conservation), an Ok answer is
/// the *correct* answer for its pinned generation (faults fail
/// requests, they never corrupt results), service stats stay monotone,
/// GC never reaps a live generation, and after `faults::clear()` a
/// replay of the same submissions is bitwise deterministic.
#[test]
fn prop_fault_schedules_conserve_tickets_and_replay_clean() {
    let _g = lock();
    let (n, m) = (96, 24);
    let (f0, f1) = factor_pair(n, m, 1e-8, 101);
    let variants = [f0.clone(), f1.clone()];
    let dir = temp_dir("prop");
    let key = 0x9B0Bu64;
    FactorStore::open(&dir).unwrap().save_chol(key, &f0, "gen 0").unwrap();
    let cfg = Config { cases: 8, max_shrink_steps: 80 };
    run_prop_with(cfg, "chaos_schedules", REGRESSIONS, &ChaosCaseStrategy, |case| {
        let opts = ServeOpts {
            max_panel: 1,
            flush_deadline: Duration::from_millis(1),
            cache_capacity: 2,
            request_deadline: Some(Duration::from_millis(500)),
            ..Default::default()
        };
        let service = SolveService::start(FactorStore::open(&dir).unwrap(), opts);
        faults::install(case_plan(&case));
        let mut expected_gen = 0u32;
        let mut in_flight = Vec::new();
        let mut submitted = 0usize;
        let mut resolved_at_submit = 0usize;
        let mut prev = service.stats();
        for (step, op) in case.ops.iter().enumerate() {
            match op {
                ChaosOp::Submit(seed) => {
                    submitted += 1;
                    let rhs = rhs_for(n, *seed as u64 + 1);
                    match service.submit(key, rhs.clone()) {
                        Ok(t) => in_flight.push((step, expected_gen, rhs, t)),
                        // A typed rejection at admission resolves the
                        // request; it is not a lost ticket.
                        Err(_) => resolved_at_submit += 1,
                    }
                }
                ChaosOp::Swap => {
                    let next = variants[(expected_gen as usize + 1) % 2].clone();
                    let id = service.swap(key, StoredFactor::Chol(next));
                    expected_gen += 1;
                    if id != (FactorId { key, generation: expected_gen }) {
                        return Err(format!("step {step}: swap returned {id}"));
                    }
                }
                ChaosOp::Collect => {
                    for c in service.collect_idle(key) {
                        if c.key != key || c.generation >= expected_gen {
                            return Err(format!("step {step}: GC reaped live id {c}"));
                        }
                    }
                }
            }
            let s = service.stats();
            if s.requests < prev.requests || s.batches < prev.batches || s.rejected < prev.rejected
            {
                return Err(format!("step {step}: service stats went backwards"));
            }
            prev = s;
        }
        let mut finished = 0usize;
        for (step, gen, rhs, t) in in_flight {
            match t.wait() {
                Ok(resp) => {
                    if resp.generation != gen {
                        return Err(format!(
                            "step {step}: admitted on gen {gen}, served by {}",
                            resp.generation
                        ));
                    }
                    // Under injected faults an Ok must still be right.
                    let x_ref = chol_solve(&variants[gen as usize % 2], &rhs);
                    let scale = x_ref.iter().fold(0.0f64, |a, &v| a.max(v.abs())).max(1.0);
                    let err =
                        resp.x.iter().zip(&x_ref).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
                    if err > 1e-10 * scale {
                        return Err(format!("step {step}: Ok answer is wrong (err {err})"));
                    }
                }
                Err(ServeError::WorkerPanicked { .. })
                | Err(ServeError::Store(_))
                | Err(ServeError::DeadlineExceeded { .. })
                | Err(ServeError::StaleGeneration { .. })
                | Err(ServeError::CorruptFactor { .. }) => {}
                Err(e) => return Err(format!("step {step}: unexpected failure class: {e}")),
            }
            finished += 1;
        }
        faults::clear();
        if finished + resolved_at_submit != submitted {
            return Err(format!(
                "conservation: {submitted} submitted, {finished} waited + \
                 {resolved_at_submit} rejected"
            ));
        }
        // Fault-free replay: the same submissions against the stored
        // gen-0 frame, twice, must agree bitwise (width-1 panels).
        let replay = |tag: &str| -> Result<Vec<Vec<f64>>, String> {
            let svc = SolveService::start(
                FactorStore::open(&dir).unwrap(),
                ServeOpts {
                    max_panel: 1,
                    flush_deadline: Duration::from_millis(1),
                    cache_capacity: 2,
                    ..Default::default()
                },
            );
            case.ops
                .iter()
                .filter_map(|op| match op {
                    ChaosOp::Submit(seed) => Some(*seed),
                    _ => None,
                })
                .map(|seed| {
                    svc.submit(key, rhs_for(n, seed as u64 + 1))
                        .map_err(|e| format!("{tag}: clean submit rejected: {e}"))?
                        .wait()
                        .map(|r| r.x)
                        .map_err(|e| format!("{tag}: clean request failed: {e}"))
                })
                .collect()
        };
        let a = replay("replay A")?;
        let b = replay("replay B")?;
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            if x.iter().zip(y).any(|(p, q)| p.to_bits() != q.to_bits()) {
                return Err(format!("fault-free replay diverged at submission {i}"));
            }
        }
        Ok(())
    });
    faults::clear();
    let _ = std::fs::remove_dir_all(&dir);
}
