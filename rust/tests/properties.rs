//! Property-based tests over randomized inputs (many seeds per
//! property). The vendored crate set has no `proptest`, so properties
//! are expressed as deterministic seed sweeps with shrink-friendly
//! assertion messages carrying the seed.

use h2opus_tlr::ara::{ara, batched_ara, AraOpts, DenseSampler, Sampler};
use h2opus_tlr::batch::DynamicBatcher;
use h2opus_tlr::factor::{cholesky, FactorOpts, Pivoting};
use h2opus_tlr::linalg::blas::{trsm_lower, Side};
use h2opus_tlr::linalg::chol::potrf;
use h2opus_tlr::linalg::gemm::{gemm, matmul, matmul_nt, matmul_tn, Trans};
use h2opus_tlr::linalg::ldl::{ldl, ldl_reconstruct, modified_cholesky};
use h2opus_tlr::linalg::qr::{householder_qr, orthog, panel_qr};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::linalg::svd::svd;
use h2opus_tlr::solve::{tlr_matvec, tlr_trsv_lower, tlr_trsv_lower_t};
use h2opus_tlr::Matrix;

const SEEDS: std::ops::Range<u64> = 0..12;

fn dims(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

// --------------------------------------------------------- gemm algebra

#[test]
fn prop_gemm_associativity_and_transpose() {
    for seed in SEEDS {
        let mut rng = Rng::new(seed);
        let (m, k, n, p) = (
            dims(&mut rng, 1, 20),
            dims(&mut rng, 1, 20),
            dims(&mut rng, 1, 20),
            dims(&mut rng, 1, 20),
        );
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c = rng.normal_matrix(n, p);
        // (AB)C == A(BC)
        let left = matmul(&matmul(&a, &b), &c);
        let right = matmul(&a, &matmul(&b, &c));
        let scale = left.norm_max().max(1.0);
        assert!(left.sub(&right).norm_max() / scale < 1e-12, "assoc seed={seed}");
        // (AB)^T == B^T A^T
        let abt = matmul(&a, &b).transpose();
        let btat = matmul(&b.transpose(), &a.transpose());
        assert!(abt.sub(&btat).norm_max() < 1e-12, "transpose seed={seed}");
        // matmul_tn agrees with the explicit transpose: Aᵀ D for
        // D with rows(A) rows.
        let d = rng.normal_matrix(m, n);
        assert!(
            matmul_tn(&a, &d).sub(&matmul(&a.transpose(), &d)).norm_max() < 1e-10,
            "tn seed={seed}"
        );
    }
}

#[test]
fn prop_gemm_alpha_beta_contract() {
    for seed in SEEDS {
        let mut rng = Rng::new(100 + seed);
        let (m, k, n) = (dims(&mut rng, 1, 16), dims(&mut rng, 1, 16), dims(&mut rng, 1, 16));
        let a = rng.normal_matrix(m, k);
        let b = rng.normal_matrix(k, n);
        let c0 = rng.normal_matrix(m, n);
        let (alpha, beta) = (rng.uniform_in(-2.0, 2.0), rng.uniform_in(-2.0, 2.0));
        let mut c = c0.clone();
        gemm(Trans::No, Trans::No, alpha, &a, &b, beta, &mut c);
        let mut want = matmul(&a, &b);
        want.scale(alpha);
        let mut c0s = c0.clone();
        c0s.scale(beta);
        want.axpy(1.0, &c0s);
        assert!(c.sub(&want).norm_max() < 1e-10, "seed={seed}");
    }
}

// ------------------------------------------------------- factorizations

#[test]
fn prop_potrf_reconstructs_and_trsm_inverts() {
    for seed in SEEDS {
        let mut rng = Rng::new(200 + seed);
        let n = dims(&mut rng, 2, 40);
        let g = rng.normal_matrix(n, n);
        let mut a = matmul_nt(&g, &g);
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        let mut l = a.clone();
        potrf(&mut l, 8).expect("spd");
        // L L^T == A (lower triangle holds L; potrf zeroes the upper).
        let rec = matmul_nt(&l, &l);
        assert!(rec.sub(&a).norm_max() / a.norm_max() < 1e-12, "potrf seed={seed}");
        // trsm: L X = B  =>  L X - B == 0.
        let b = rng.normal_matrix(n, 3);
        let mut x = b.clone();
        trsm_lower(Side::Left, Trans::No, &l, &mut x);
        assert!(matmul(&l, &x).sub(&b).norm_max() < 1e-9, "trsm seed={seed}");
    }
}

#[test]
fn prop_ldl_matches_inertia_of_input() {
    for seed in SEEDS {
        let mut rng = Rng::new(300 + seed);
        let n = dims(&mut rng, 2, 24);
        let mut a = rng.normal_matrix(n, n);
        a.symmetrize();
        // Push eigenvalues away from zero to keep LDL^T pivots stable.
        for i in 0..n {
            a[(i, i)] += if i % 2 == 0 { 6.0 } else { -6.0 } * (1.0 + n as f64 / 8.0);
        }
        let f = match ldl(&a) {
            Ok(f) => f,
            Err(_) => continue, // genuinely singular pivot: skip this draw
        };
        let rec = ldl_reconstruct(&f);
        assert!(rec.sub(&a).norm_max() / a.norm_max() < 1e-9, "ldl seed={seed}");
    }
}

#[test]
fn prop_modified_cholesky_always_yields_spd_factor() {
    for seed in SEEDS {
        let mut rng = Rng::new(400 + seed);
        let n = dims(&mut rng, 2, 24);
        let mut a = rng.normal_matrix(n, n);
        a.symmetrize(); // indefinite in general
        let m = modified_cholesky(&a, 1e-8).expect("modchol");
        // L L^T = A + E with E symmetric PSD-ish: check A + E is what L
        // reconstructs and that the factorization is usable.
        let rec = matmul_nt(&m.l, &m.l);
        let e = rec.sub(&a);
        // E should vanish when A is already SPD.
        let mut spd = matmul_nt(&rng.normal_matrix(n, n), &rng.normal_matrix(n, n));
        spd.symmetrize();
        let _ = spd;
        assert!(e.norm_max().is_finite(), "seed={seed}");
        // diag of L strictly positive
        for i in 0..n {
            assert!(m.l[(i, i)] > 0.0, "seed={seed} i={i}");
        }
    }
}

// ------------------------------------------------------------------ qr

#[test]
fn prop_qr_orthonormal_and_reconstructs() {
    for seed in SEEDS {
        let mut rng = Rng::new(500 + seed);
        let m = dims(&mut rng, 2, 40);
        let n = dims(&mut rng, 1, m);
        let a = rng.normal_matrix(m, n);
        for (q, r) in [householder_qr(&a), panel_qr(&a)] {
            let qtq = matmul_tn(&q, &q);
            assert!(qtq.sub(&Matrix::identity(n)).norm_max() < 1e-10, "Q'Q seed={seed}");
            assert!(matmul(&q, &r).sub(&a).norm_max() < 1e-9, "QR seed={seed}");
        }
    }
}

#[test]
fn prop_orthog_extends_basis() {
    for seed in SEEDS {
        let mut rng = Rng::new(600 + seed);
        let m = dims(&mut rng, 8, 40);
        let k0 = dims(&mut rng, 1, m / 2);
        let knew = dims(&mut rng, 1, m / 4);
        let (q0, _) = panel_qr(&rng.normal_matrix(m, k0));
        let y = rng.normal_matrix(m, knew);
        let o = orthog(&q0, &y);
        // New block orthogonal to old basis and internally orthonormal.
        if o.q_new.cols() > 0 {
            assert!(matmul_tn(&q0, &o.q_new).norm_max() < 1e-9, "seed={seed}");
            let i = Matrix::identity(o.q_new.cols());
            assert!(matmul_tn(&o.q_new, &o.q_new).sub(&i).norm_max() < 1e-9, "seed={seed}");
        }
    }
}

// ----------------------------------------------------------------- svd

#[test]
fn prop_svd_reconstructs_with_descending_values() {
    for seed in SEEDS {
        let mut rng = Rng::new(700 + seed);
        let m = dims(&mut rng, 2, 24);
        let n = dims(&mut rng, 2, 24);
        let a = rng.normal_matrix(m, n);
        let s = svd(&a);
        assert!(s.s.windows(2).all(|w| w[0] >= w[1] - 1e-12), "order seed={seed}");
        assert!(s.s.iter().all(|&x| x >= -1e-12), "sign seed={seed}");
        // Reconstruction through truncate at full rank.
        let k = s.s.len();
        let (u, v) = s.truncate(k);
        let rec = matmul_nt(&u, &v);
        assert!(rec.sub(&a).norm_max() < 1e-8, "recon seed={seed}");
        // rank_for_tol monotonicity.
        assert!(s.rank_for_tol(1e-12) >= s.rank_for_tol(1e-2), "mono seed={seed}");
    }
}

// ----------------------------------------------------------------- ara

#[test]
fn prop_ara_rank_and_error_bounds() {
    for seed in SEEDS {
        let mut rng = Rng::new(800 + seed);
        let m = dims(&mut rng, 10, 50);
        let n = dims(&mut rng, 10, 50);
        let true_k = dims(&mut rng, 1, 6);
        let u = rng.normal_matrix(m, true_k);
        let v = rng.normal_matrix(n, true_k);
        let a = matmul_nt(&u, &v);
        let s = DenseSampler(&a);
        let mut arng = Rng::new(9000 + seed);
        let bs = 1 + rng.below(6);
        // Untrimmed: Q stays orthonormal and rank lands within one block
        // of the true rank.
        let mut opts = AraOpts::new(bs, 1e-9);
        opts.trim = false;
        let r = ara(&s, &opts, &mut arng);
        assert!(r.lr.rank() <= m.min(n), "rank cap seed={seed}");
        let got = r.lr.rank();
        assert!(got <= true_k + bs, "rank={got} true={true_k} bs={bs} seed={seed}");
        let err = r.lr.to_dense().sub(&a).norm_fro();
        assert!(err < 1e-6, "err={err} seed={seed}");
        if r.lr.rank() > 0 {
            let i = Matrix::identity(r.lr.rank());
            assert!(matmul_tn(&r.lr.u, &r.lr.u).sub(&i).norm_max() < 1e-9, "seed={seed}");
        }
        // Trimmed (the factorization default): rank shrinks to the true
        // rank exactly (exact low-rank input) at no accuracy cost.
        let mut arng = Rng::new(9000 + seed);
        opts.trim = true;
        let rt = ara(&s, &opts, &mut arng);
        assert!(rt.lr.rank() <= r.lr.rank(), "trim grew rank seed={seed}");
        assert_eq!(rt.lr.rank(), true_k.min(rt.lr.rank().max(true_k)), "trim rank seed={seed}");
        let err = rt.lr.to_dense().sub(&a).norm_fro();
        assert!(err < 1e-6, "trimmed err={err} seed={seed}");
    }
}

// ------------------------------------------------- dynamic batch scheduler

#[test]
fn prop_dynamic_batcher_invariants() {
    for seed in SEEDS {
        let mut rng = Rng::new(900 + seed);
        let n = 1 + rng.below(40);
        let capacity = 1 + rng.below(10);
        let priorities: Vec<usize> = (0..n).map(|_| rng.below(100)).collect();
        // Rounds each item needs before it "converges".
        let need: Vec<usize> = (0..n).map(|_| 1 + rng.below(5)).collect();
        let mut done = vec![0usize; n];
        let mut batcher = DynamicBatcher::new(&priorities, capacity);

        // Admission order respects priorities: reconstruct the first
        // `capacity` admitted.
        let mut sorted: Vec<usize> = (0..n).collect();
        sorted.sort_by(|&a, &b| priorities[b].cmp(&priorities[a]).then(a.cmp(&b)));
        let first: Vec<usize> = batcher.active().to_vec();
        assert_eq!(first, sorted[..capacity.min(n)].to_vec(), "admission seed={seed}");

        let mut seen_after_retire = false;
        let mut retired = vec![false; n];
        let mut rounds = 0;
        while !batcher.is_done() {
            rounds += 1;
            assert!(rounds < 10_000, "livelock seed={seed}");
            let active = batcher.active().to_vec();
            assert!(active.len() <= capacity, "overflow seed={seed}");
            // No retired item may reappear.
            for &i in &active {
                if retired[i] {
                    seen_after_retire = true;
                }
            }
            let converged: Vec<bool> = active
                .iter()
                .map(|&i| {
                    done[i] += 1;
                    done[i] >= need[i]
                })
                .collect();
            for (pos, &i) in active.iter().enumerate() {
                if converged[pos] {
                    retired[i] = true;
                }
            }
            batcher.complete_round(&converged);
        }
        assert!(!seen_after_retire, "retired item reappeared seed={seed}");
        assert!(batcher.all_retired(), "missing retirements seed={seed}");
        // Every item processed exactly `need` rounds.
        for i in 0..n {
            assert_eq!(done[i], need[i], "item {i} seed={seed}");
            assert_eq!(batcher.stats().item_rounds[i], need[i], "stats {i} seed={seed}");
        }
    }
}

#[test]
fn prop_batched_ara_capacity_invariance() {
    // The factorization-visible property: results do not depend on the
    // batch capacity (only scheduling does).
    for seed in 0..4u64 {
        let mut rng = Rng::new(1000 + seed);
        let mats: Vec<Matrix> = (0..6)
            .map(|_| {
                let k = 1 + rng.below(5);
                let u = rng.normal_matrix(24, k);
                let v = rng.normal_matrix(24, k);
                matmul_nt(&u, &v)
            })
            .collect();
        let samplers: Vec<DenseSampler> = mats.iter().map(DenseSampler).collect();
        let ops: Vec<&dyn Sampler> = samplers.iter().map(|s| s as &dyn Sampler).collect();
        let prios = vec![0usize; 6];
        let opts = AraOpts::new(4, 1e-9);
        let base = batched_ara(&ops, &prios, 1, &opts, 31 + seed);
        for cap in [2usize, 3, 6, 50] {
            let other = batched_ara(&ops, &prios, cap, &opts, 31 + seed);
            for (x, y) in base.tiles.iter().zip(&other.tiles) {
                assert_eq!(x.rank(), y.rank(), "cap={cap} seed={seed}");
                assert!(
                    x.to_dense().sub(&y.to_dense()).norm_max() < 1e-12,
                    "cap={cap} seed={seed}"
                );
            }
        }
    }
}

// ------------------------------------------------------------- TLR ops

fn random_cov_tlr(seed: u64) -> (h2opus_tlr::TlrMatrix, Matrix) {
    use h2opus_tlr::apps::covariance::ExpCovariance;
    use h2opus_tlr::apps::geometry::random_ball;
    use h2opus_tlr::apps::kdtree::kdtree_order;
    use h2opus_tlr::apps::matgen::MatGen;
    use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
    let mut rng = Rng::new(seed);
    let n = 120 + rng.below(200);
    let m = 24 + rng.below(40);
    let pts = random_ball(n, 3, seed);
    let c = kdtree_order(&pts, m);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let tlr = build_tlr(
        &cov,
        &c.offsets,
        &BuildOpts { eps: 1e-9, method: Compression::Ara { bs: 4 }, seed },
    );
    (tlr, cov.dense())
}

#[test]
fn prop_tlr_matvec_matches_dense() {
    for seed in 0..6u64 {
        let (tlr, dense) = random_cov_tlr(1100 + seed);
        let n = dense.rows();
        let mut rng = Rng::new(1200 + seed);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let got = tlr_matvec(&tlr, &x);
        let want = dense.matvec(&x);
        let err = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-6, "seed={seed} err={err}");
    }
}

#[test]
fn prop_tlr_trsv_inverts_matvec() {
    for seed in 0..6u64 {
        let (tlr, _) = random_cov_tlr(1300 + seed);
        let f = cholesky(tlr, &FactorOpts { eps: 1e-9, bs: 4, ..Default::default() }).unwrap();
        let n = f.l.n();
        let mut rng = Rng::new(1400 + seed);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // trsv_lower(L, L x) == x and the transpose pair.
        let lx = h2opus_tlr::solve::tlr_matvec_lower(&f.l, &x);
        let back = tlr_trsv_lower(&f.l, &lx);
        let err = back.iter().zip(&x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-7, "trsv seed={seed} err={err}");
        let ltx = h2opus_tlr::solve::tlr_matvec_lower_t(&f.l, &x);
        let back = tlr_trsv_lower_t(&f.l, &ltx);
        let err = back.iter().zip(&x).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-7, "trsv_t seed={seed} err={err}");
    }
}

#[test]
fn prop_pivoted_perm_is_valid_permutation() {
    for (seed, pivot) in [(0u64, Pivoting::Frobenius), (1, Pivoting::Norm2), (2, Pivoting::Random)]
    {
        use h2opus_tlr::apps::covariance::ExpCovariance;
        use h2opus_tlr::apps::geometry::grid;
        use h2opus_tlr::apps::kdtree::kdtree_order;
        use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
        let n = 256;
        let pts = grid(n, 2);
        let c = kdtree_order(&pts, 64);
        let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
        let tlr = build_tlr(
            &cov,
            &c.offsets,
            &BuildOpts { eps: 1e-8, method: Compression::Svd, seed },
        );
        let f = cholesky(tlr, &FactorOpts { eps: 1e-8, bs: 8, pivot, ..Default::default() })
            .unwrap();
        // Tile perm is a permutation of 0..nb.
        let mut sorted = f.stats.perm.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..f.l.nb()).collect::<Vec<_>>(), "{pivot:?}");
        // Scalar perm is a permutation of 0..n.
        let mut sp = f.scalar_perm();
        sp.sort_unstable();
        assert_eq!(sp, (0..n).collect::<Vec<_>>(), "{pivot:?}");
    }
}

// ------------------------------------------------------ failure injection

#[test]
fn prop_cholesky_rejects_indefinite_at_any_block() {
    for seed in 0..4u64 {
        let (mut tlr, _) = random_cov_tlr(1500 + seed);
        let nb = tlr.nb();
        let target = (seed as usize) % nb;
        if let h2opus_tlr::tlr::tile::Tile::Dense(d) = tlr.tile_mut(target, target) {
            let rows = d.rows();
            for i in 0..rows {
                d[(i, i)] -= 50.0;
            }
        }
        match cholesky(tlr, &FactorOpts { eps: 1e-9, bs: 4, ..Default::default() }) {
            Err(h2opus_tlr::factor::FactorError::NotSpd { block, .. }) => {
                assert!(block <= target, "failure after the poisoned block (seed={seed})");
            }
            other => {
                panic!("expected NotSpd, got {:?}", other.map(|_| ()).map_err(|e| e.to_string()))
            }
        }
    }
}

#[test]
fn prop_zero_matrix_factors_to_zero_ranks() {
    use h2opus_tlr::tlr::matrix::TlrMatrix;
    use h2opus_tlr::tlr::tile::Tile;
    // A+I with zero off-diagonal tiles: factor must keep ranks at 0.
    let offsets = vec![0usize, 16, 32, 48];
    let mut tlr = TlrMatrix::zeros(offsets);
    for k in 0..3 {
        if let Tile::Dense(d) = tlr.tile_mut(k, k) {
            for i in 0..16 {
                d[(i, i)] = 2.0;
            }
        }
    }
    let f = cholesky(tlr, &FactorOpts { eps: 1e-10, bs: 4, ..Default::default() }).unwrap();
    assert!(f.l.offdiag_ranks().iter().all(|&r| r == 0));
}

// ------------------------------------------------------ mixed precision

fn assert_close(a: &Matrix, b: &Matrix, tol: f64, what: &str) {
    let scale = a.norm_max().max(b.norm_max()).max(1.0);
    let err = a.sub(b).norm_max();
    assert!(err <= tol * scale, "{what}: |diff| {err:.3e} > {tol:.0e} * {scale:.3e}");
}

#[test]
fn prop_mixed_tiles_native_matches_ref_batch() {
    use h2opus_tlr::batch::{NativeBatch, RefBatch, StreamBuilder};
    use h2opus_tlr::tlr::tile::{LowRank, LowRank32, Tile};
    for seed in 0..8u64 {
        let mut rng = Rng::new(4000 + seed);
        let (m, n, bs) = (
            dims(&mut rng, 4, 40),
            dims(&mut rng, 4, 40),
            dims(&mut rng, 1, 12),
        );
        let r = dims(&mut rng, 1, 6);
        let lr = LowRank { u: rng.normal_matrix(m, r), v: rng.normal_matrix(n, r) };
        let t32 = Tile::LowRank32(LowRank32::from_f64(&lr));
        let t64 = Tile::LowRank(lr);
        let x = rng.normal_matrix(n, bs);
        let xt = rng.normal_matrix(m, bs);
        let mut sb = StreamBuilder::new();
        let xin = sb.input(&x);
        let xtin = sb.input(&xt);
        let d0 = sb.output(m, bs);
        let d1 = sb.output(n, bs);
        let d2 = sb.output(m, bs);
        sb.apply_tile(&t32, xin, 1.0, d0, false);
        sb.apply_tile(&t32, xtin, -0.5, d1, true);
        sb.apply_tile(&t64, xin, 1.0, d2, false);
        let stream = sb.finish();
        stream.plan().assert_valid();
        let native = stream.execute(&NativeBatch::new());
        let oracle = stream.execute(&RefBatch);
        for slot in [d0, d1, d2] {
            assert_close(
                &native[slot],
                &oracle[slot],
                1e-13,
                &format!("seed={seed} slot={slot}"),
            );
        }
        // The mixed tile is an exact widening of its f32 factors, so the
        // forward apply must also match the f64 tile built from them.
        let widened = match &t32 {
            Tile::LowRank32(l) => Tile::LowRank(l.to_f64()),
            _ => unreachable!(),
        };
        let mut sb2 = StreamBuilder::new();
        let xin2 = sb2.input(&x);
        let dw = sb2.output(m, bs);
        sb2.apply_tile(&widened, xin2, 1.0, dw, false);
        let wide = sb2.finish().execute(&NativeBatch::new());
        assert_close(&native[d0], &wide[dw], 1e-13, &format!("seed={seed} widened"));
    }
}

#[test]
fn mixed_factor_pcg_iteration_parity_and_bytes() {
    use h2opus_tlr::apps::covariance::ExpCovariance;
    use h2opus_tlr::apps::geometry::random_ball;
    use h2opus_tlr::apps::kdtree::kdtree_order;
    use h2opus_tlr::solve::{chol_solve, pcg, TlrOp};
    use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
    use h2opus_tlr::tlr::demote_offdiag;
    let eps = 1e-6;
    let pts = random_ball(300, 3, 77);
    let c = kdtree_order(&pts, 48);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let tlr = build_tlr(
        &cov,
        &c.offsets,
        &BuildOpts { eps, method: Compression::Ara { bs: 8 }, seed: 77 },
    );
    let a_op = tlr.clone();
    let f64_factor =
        cholesky(tlr, &FactorOpts { eps, bs: 8, shift: eps, ..Default::default() }).unwrap();
    // Demote the clone: the acceptance bar is >= 1.4x lower off-diagonal
    // bytes at the factorization tolerance...
    let mut mixed = f64_factor.clone();
    let before = mixed.l.memory();
    let stats = demote_offdiag(&mut mixed.l, eps);
    let after = mixed.l.memory();
    assert!(stats.demoted > 0, "no tiles were eligible for f32 storage");
    let ratio = before.lowrank_f64 as f64 / after.lowrank_f64 as f64;
    assert!(
        ratio >= 1.4,
        "off-diagonal factor bytes shrank only {ratio:.2}x (demoted {} / kept {})",
        stats.demoted,
        stats.kept
    );
    // ...with an identical PCG iteration count against the same operator.
    let n = a_op.n();
    let mut rng = Rng::new(78);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let r64 = pcg(&TlrOp(&a_op), &|r| chol_solve(&f64_factor, r), &b, eps, 200);
    let rmx = pcg(&TlrOp(&a_op), &|r| chol_solve(&mixed, r), &b, eps, 200);
    assert!(r64.converged, "f64-preconditioned pcg stalled at {} iters", r64.iters);
    assert!(rmx.converged, "mixed-preconditioned pcg stalled at {} iters", rmx.iters);
    assert_eq!(
        r64.iters, rmx.iters,
        "f32 tile storage moved the preconditioned iteration count"
    );
}
