//! CLI smoke tests: run the real `h2opus-tlr` binary end-to-end on small
//! problems and assert on exit codes and key output lines.

use std::process::Command;

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_h2opus-tlr"))
        .args(args)
        .output()
        .expect("binary must run");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_prints_usage() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    assert!(text.contains("SUBCOMMANDS"));
    assert!(text.contains("--backend"));
}

#[test]
fn no_args_fails_with_usage() {
    let (ok, text) = run(&[]);
    assert!(!ok);
    assert!(text.contains("USAGE"));
}

#[test]
fn unknown_flag_is_rejected() {
    let (ok, text) = run(&["factor", "--bogus", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown option"));
}

#[test]
fn factor_small_cov2d() {
    let (ok, text) = run(&[
        "factor", "--problem", "cov2d", "--n", "256", "--m", "64", "--eps", "1e-6", "--bs", "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("memory"), "{text}");
    assert!(text.contains("verify"), "{text}");
    assert!(text.contains("GEMM-shaped"), "{text}");
}

#[test]
fn solve_with_shift_runs_pcg() {
    let (ok, text) = run(&[
        "solve", "--problem", "fracdiff", "--n", "256", "--m", "64", "--eps", "1e-3", "--shift",
        "-1", "--bs", "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("pcg"), "{text}");
    assert!(text.contains("converged=true"), "{text}");
}

#[test]
fn ldlt_factor_runs() {
    let (ok, text) = run(&[
        "factor", "--problem", "cov2d", "--n", "256", "--m", "64", "--eps", "1e-6", "--ldlt",
        "--bs", "8",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("min diagonal entry"), "{text}");
}

#[test]
fn info_prints_histogram() {
    let (ok, text) =
        run(&["info", "--problem", "cov3d-ball", "--n", "256", "--m", "64", "--bs", "8"]);
    assert!(ok, "{text}");
    assert!(text.contains("rank histogram"), "{text}");
}

#[test]
fn verify_exercises_artifacts_when_present() {
    if !std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
    {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (ok, text) = run(&["verify"]);
    assert!(ok, "{text}");
    assert!(text.contains("all artifacts OK"), "{text}");
}

#[test]
fn pjrt_backend_smoke() {
    if !std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
    {
        return;
    }
    let (ok, text) = run(&[
        "factor", "--problem", "cov2d", "--n", "256", "--m", "64", "--eps", "1e-4", "--bs", "8",
        "--backend", "pjrt",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("verify"), "{text}");
}
