//! Integration tests for the `obs/` observability layer, in its own
//! process so the global-state tests (histogram resets, the flight
//! recorder ring) cannot race the lower-bound assertions of the other
//! test binaries:
//!
//! * histogram percentiles track exact sample quantiles to within one
//!   √2 bucket, merges equal unions, and `since` never underflows even
//!   with a reset or concurrent writers in between;
//! * the flight recorder survives a 10k-event multi-threaded flood
//!   without exceeding its capacity, and its JSON-lines dump
//!   round-trips — property-tested over arbitrary event sequences
//!   (hex-framed u64 fields above 2^53 included) and arbitrary
//!   flood shapes on the in-tree proptest runner;
//! * a two-tenant [`SolveService`] run reports per-key p50/p95/p99
//!   request-wait and execution latencies from the histograms;
//! * [`obs::prometheus`] output parses line by line against the text
//!   exposition grammar;
//! * a sharded run's flight-recorder dump reconstructs a full request
//!   timeline: Submitted → Enqueued → Coalesced → Executed → Responded
//!   with strictly increasing sequence numbers.

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, CholFactor, FactorOpts};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::obs::{self, EventKind, FlightRecorder, HistId, Histogram, RejectReason};
use h2opus_tlr::serve::{
    FactorStore, ServeOpts, ShardMap, ShardedService, SolveService, StoredFactor,
};
use h2opus_tlr::testing::proptest::{no_panic, run_prop, run_prop_with, Config, Strategy};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use std::path::PathBuf;
use std::time::Duration;

/// Pinned counterexample seeds, replayed before any fresh generation.
const REGRESSIONS: &str = include_str!("proptest-regressions/obs.txt");

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("h2opus_obs_test_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One small Cholesky factor (the serve tests' recipe).
fn small_factor(seed: u64) -> CholFactor {
    let pts = grid(128, 2);
    let c = kdtree_order(&pts, 32);
    let cov = ExpCovariance::paper_default(pts.permuted(&c.perm));
    let tlr = build_tlr(
        &cov,
        &c.offsets,
        &BuildOpts { eps: 1e-6, method: Compression::Svd, seed },
    );
    cholesky(tlr, &FactorOpts { eps: 1e-6, bs: 8, ..Default::default() }).unwrap()
}

// ------------------------------------------------- histogram properties

#[test]
fn percentiles_track_exact_quantiles_across_seeds() {
    for seed in 0..6u64 {
        let mut rng = Rng::new(0x0B5E + seed);
        let h = Histogram::new();
        let mut vals: Vec<u64> = Vec::new();
        for i in 0..1500usize {
            // Mixed regimes: small counts, mid-range ns, heavy tail.
            let v = match i % 3 {
                0 => rng.below(64) as u64,
                1 => 1_000 + rng.below(1_000_000) as u64,
                _ => (1u64 << (10 + rng.below(20) as u64)) + rng.below(512) as u64,
            };
            vals.push(v);
            h.record(v);
        }
        vals.sort_unstable();
        let snap = h.snapshot();
        let mut prev = 0.0f64;
        for q in [0.05, 0.25, 0.5, 0.9, 0.95, 0.99] {
            let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let exact = vals[rank - 1];
            let est = snap.percentile(q);
            assert_eq!(
                obs::bucket_index(est as u64),
                obs::bucket_index(exact),
                "seed={seed} q={q}: est {est} vs exact {exact}"
            );
            assert!(est >= prev, "seed={seed} q={q}: percentiles not monotone");
            prev = est;
        }
    }
}

#[test]
fn merge_matches_union_across_seeds() {
    for seed in 0..4u64 {
        let mut rng = Rng::new(0x3E46E + seed);
        let (a, b, both) = (Histogram::new(), Histogram::new(), Histogram::new());
        for _ in 0..800 {
            let v = rng.below(1 << 22) as u64;
            if rng.below(2) == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        assert_eq!(a.snapshot().merge(&b.snapshot()), both.snapshot(), "seed={seed}");
    }
}

#[test]
fn since_never_underflows_under_concurrent_recording() {
    let h = Histogram::new();
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let h = &h;
            scope.spawn(move || {
                let mut rng = Rng::new(0xC0 + t);
                for _ in 0..20_000 {
                    h.record(rng.below(1 << 20) as u64);
                }
            });
        }
        // Interleaved snapshot pairs while writers are hot: deltas must
        // be non-negative (saturating) and counts monotone.
        for _ in 0..200 {
            let s1 = h.snapshot();
            let s2 = h.snapshot();
            let d = s2.since(&s1);
            assert!(s2.count >= s1.count);
            assert!(d.bucket_total() <= s2.bucket_total());
        }
    });
    // Writers quiesced: totals are exact.
    let fin = h.snapshot();
    assert_eq!(fin.count, 80_000);
    assert_eq!(fin.bucket_total(), 80_000);
}

#[test]
fn global_since_survives_interleaved_resets() {
    // The live-global counterpart of profile.rs's struct-level
    // regression test: a reset between two snapshots must yield a
    // saturated (all-small) delta, never an underflow panic. Loose
    // bounds only — other tests in this binary record concurrently.
    obs::histogram(HistId::PcgIters).record(3);
    let before = obs::snapshot();
    h2opus_tlr::profile::reset();
    obs::reset_histograms();
    obs::histogram(HistId::PcgIters).record(1);
    let after = obs::snapshot();
    let d = after.since(&before);
    let i = HistId::PcgIters as usize;
    assert!(d.hists[i].bucket_total() <= after.hists[i].bucket_total());
    assert!(d.serve.requests <= after.serve.requests);
}

// ------------------------------------------------ flight recorder ring

#[test]
fn recorder_flood_respects_capacity_and_never_blocks() {
    let r = FlightRecorder::with_capacity(1024);
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let r = &r;
            scope.spawn(move || {
                for i in 0..2500u64 {
                    r.record(t * 10_000 + i, EventKind::Executed { waves: 1, ns: i });
                }
            });
        }
    });
    assert_eq!(r.recorded(), 10_000);
    let ev = r.events();
    assert!(ev.len() <= r.capacity(), "ring exceeded capacity: {}", ev.len());
    assert!(!ev.is_empty());
    assert!(ev.windows(2).all(|w| w[0].seq < w[1].seq), "seqs not strictly increasing");
}

#[test]
fn dump_json_lines_round_trips_through_files() {
    let r = FlightRecorder::with_capacity(32);
    r.record(5, EventKind::Submitted);
    r.record(5, EventKind::Enqueued { key: 0xFFFF_FFFF_FFFF_FFFF });
    r.record(5, EventKind::Coalesced { panel: 3, width: 2 });
    r.record(5, EventKind::Executed { waves: 4, ns: 987 });
    r.record(5, EventKind::Responded);
    let dir = temp_dir("trace_dump");
    let path = dir.join("trace.jsonl");
    std::fs::write(&path, r.dump_json_lines()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed: Vec<_> = text
        .lines()
        .map(|l| {
            let v = h2opus_tlr::runtime::json::parse(l).expect("line parses");
            obs::Event::from_json(&v).expect("event decodes")
        })
        .collect();
    assert_eq!(parsed, r.events());
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------- trace round-trip property

/// Arbitrary event sequences. Hex-framed u64 fields (`key`, `panel`,
/// `bytes`) take any value including above 2^53; `ns` stays under 2^53
/// per the schema (it is a JSON number — EXPERIMENTS.md
/// §Observability), and the u32 fields take any u32.
struct EventSeqStrategy;
impl Strategy for EventSeqStrategy {
    type Value = Vec<(u64, EventKind)>;

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let reasons = [
            RejectReason::UnknownFactor,
            RejectReason::UnknownMatrix,
            RejectReason::Store,
            RejectReason::BadRhs,
            RejectReason::Overloaded,
            RejectReason::Canceled,
        ];
        (0..1 + rng.below(24))
            .map(|_| {
                // Bias toward extreme values: ~half the arbitrary u64
                // fields are u64::MAX - small, the rest uniform.
                let mut big = |rng: &mut Rng| {
                    if rng.below(2) == 0 {
                        u64::MAX - rng.below(16) as u64
                    } else {
                        rng.next_u64()
                    }
                };
                let kind = match rng.below(9) {
                    0 => EventKind::Submitted,
                    1 => EventKind::Enqueued { key: big(rng) },
                    2 => EventKind::Coalesced {
                        panel: big(rng),
                        width: rng.next_u64() as u32,
                    },
                    3 => EventKind::Executed {
                        waves: rng.next_u64() as u32,
                        ns: rng.next_u64() % (1 << 53),
                    },
                    4 => EventKind::Responded,
                    5 => EventKind::Rejected { reason: reasons[rng.below(reasons.len())] },
                    6 => EventKind::RebalanceStarted,
                    7 => EventKind::RebalanceFinished { moved: rng.next_u64() as u32 },
                    _ => EventKind::Evicted { bytes: big(rng) },
                };
                (rng.next_u64() % (1 << 53), kind)
            })
            .collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            for i in 0..v.len() {
                let mut c = v.clone();
                c.remove(i);
                out.push(c);
            }
        }
        // Zero the request id of each event in turn (isolates whether
        // the failure depends on the id or the kind).
        for i in 0..v.len().min(8) {
            if v[i].0 != 0 {
                let mut c = v.clone();
                c[i].0 = 0;
                out.push(c);
            }
        }
        out
    }
}

/// Any event sequence dumps to JSON lines and parses back identically
/// — including u64::MAX keys/panels/byte counts, which cross the dump
/// as hex strings precisely because f64 JSON numbers lose integers
/// above 2^53.
#[test]
fn prop_trace_json_lines_round_trip_arbitrary_events() {
    run_prop("trace_roundtrip", REGRESSIONS, &EventSeqStrategy, |events| {
        let cap = events.len().next_power_of_two().max(2);
        let r = FlightRecorder::with_capacity(cap);
        for (req, kind) in events {
            r.record(*req, *kind);
        }
        let recorded = r.events();
        if recorded.len() != events.len() {
            return Err(format!(
                "{} events recorded, {} read back",
                events.len(),
                recorded.len()
            ));
        }
        let dump = r.dump_json_lines();
        let mut parsed = Vec::new();
        for (i, line) in dump.lines().enumerate() {
            let v = h2opus_tlr::runtime::json::parse(line)
                .map_err(|e| format!("line {i} does not parse: {e:?}"))?;
            parsed.push(
                obs::Event::from_json(&v).ok_or_else(|| format!("line {i} does not decode"))?,
            );
        }
        if parsed != recorded {
            return Err("parsed events differ from recorded events".into());
        }
        Ok(())
    });
}

/// The seqlock reader never panics — and never yields a torn or
/// invalid event — while writer threads flood a deliberately tiny
/// ring, forcing constant wrap-around mid-read.
#[test]
fn prop_torn_slot_reader_survives_concurrent_flood() {
    #[derive(Clone, Debug)]
    struct Flood {
        cap: usize,
        writers: usize,
        per_writer: usize,
        reads: usize,
    }
    struct FloodStrategy;
    impl Strategy for FloodStrategy {
        type Value = Flood;
        fn generate(&self, rng: &mut Rng) -> Flood {
            Flood {
                cap: 1 << rng.below(5),           // 1..16 slots: wraps constantly
                writers: 2 + rng.below(3),        // 2..=4 threads
                per_writer: 200 + rng.below(800), // enough to overlap reads
                reads: 20 + rng.below(40),
            }
        }
        fn shrink(&self, v: &Flood) -> Vec<Flood> {
            let mut out = Vec::new();
            if v.writers > 2 {
                out.push(Flood { writers: v.writers - 1, ..v.clone() });
            }
            if v.per_writer > 200 {
                out.push(Flood { per_writer: v.per_writer / 2, ..v.clone() });
            }
            if v.reads > 20 {
                out.push(Flood { reads: v.reads / 2, ..v.clone() });
            }
            out
        }
    }
    // Thread churn per case keeps the sweep small; the flood itself is
    // already highly randomized by the scheduler.
    let cfg = Config { cases: 12, max_shrink_steps: 60 };
    run_prop_with(cfg, "trace_torn_flood", REGRESSIONS, &FloodStrategy, |fl| {
        let r = FlightRecorder::with_capacity(fl.cap);
        no_panic("concurrent events() under flood", || {
            std::thread::scope(|scope| {
                for t in 0..fl.writers as u64 {
                    let r = &r;
                    let per = fl.per_writer as u64;
                    scope.spawn(move || {
                        for i in 0..per {
                            r.record(t * 1_000_000 + i, EventKind::Executed { waves: 1, ns: i });
                        }
                    });
                }
                // Read concurrently with the flood: every snapshot must
                // be valid (bounded, strictly ordered) even when every
                // slot is being rewritten under the reader.
                for _ in 0..fl.reads {
                    let ev = r.events();
                    assert!(ev.len() <= r.capacity(), "ring exceeded capacity");
                    assert!(
                        ev.windows(2).all(|w| w[0].seq < w[1].seq),
                        "seqs not strictly increasing"
                    );
                    let _ = r.dump_json_lines();
                }
            });
        })
    });
}

// ------------------------------------- per-key latency, two-tenant run

#[test]
fn two_tenant_service_reports_per_key_percentiles() {
    let n = 128;
    let f = small_factor(0x0B5);
    let dir = temp_dir("two_tenant");
    let service = SolveService::start(
        FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 8,
            flush_deadline: Duration::from_millis(3),
            ..Default::default()
        },
    );
    let (ka, kb) = (0xA11CEu64, 0xB0Bu64);
    service.register(ka, StoredFactor::Chol(f.clone()));
    service.register(kb, StoredFactor::Chol(f));
    let mut rng = Rng::new(0x7E);
    let per_key = 24usize;
    let tickets: Vec<_> = (0..per_key * 2)
        .map(|i| {
            let key = if i % 2 == 0 { ka } else { kb };
            let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            service.submit(key, rhs).unwrap()
        })
        .collect();
    for t in tickets {
        assert_eq!(t.wait().unwrap().x.len(), n);
    }
    let observed = service.observed_keys();
    assert!(observed.contains(&ka) && observed.contains(&kb), "keys {observed:?}");
    for key in [ka, kb] {
        let kh = service.key_hists(key).expect("key has histograms");
        // Every admitted request of this key recorded one wait and one
        // exec sample.
        assert_eq!(kh.wait.bucket_total(), per_key as u64, "key {key:x} wait count");
        assert_eq!(kh.exec.bucket_total(), per_key as u64, "key {key:x} exec count");
        for s in [&kh.wait, &kh.exec] {
            let (p50, p95, p99) = (s.percentile(0.5), s.percentile(0.95), s.percentile(0.99));
            assert!(!p50.is_nan() && !p95.is_nan() && !p99.is_nan(), "key {key:x}");
            assert!(p95 >= p50 && p99 >= p95, "key {key:x}: {p50} {p95} {p99}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------------------------- exporter grammar

/// Validate one metric-sample line: `name[{k="v",...}] value`.
fn check_sample_line(line: &str) {
    let name_ok = |s: &str| {
        !s.is_empty()
            && s.chars().next().unwrap().is_ascii_alphabetic()
            && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
    };
    let (head, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
    assert!(value.parse::<f64>().is_ok(), "bad value in: {line}");
    let name = if let Some((name, rest)) = head.split_once('{') {
        let labels = rest.strip_suffix('}').unwrap_or_else(|| panic!("unclosed {{: {line}"));
        for pair in labels.split(',') {
            let (k, v) = pair.split_once('=').unwrap_or_else(|| panic!("bad label: {line}"));
            assert!(name_ok(k), "bad label name in: {line}");
            assert!(
                v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                "unquoted label value in: {line}"
            );
        }
        name
    } else {
        head
    };
    assert!(name_ok(name), "bad metric name in: {line}");
    assert!(name.starts_with("h2opus_"), "unprefixed metric: {line}");
}

#[test]
fn prometheus_output_parses_line_by_line() {
    // Make sure at least one histogram and the serve counters have data.
    obs::histogram(HistId::RequestWait).record(1_000);
    obs::histogram(HistId::RequestWait).record(5_000_000);
    obs::histogram(HistId::WaveExec).record(123);
    let text = obs::prometheus();
    assert!(!text.is_empty());
    let mut samples = 0usize;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let (name, ty) = (it.next().unwrap_or(""), it.next().unwrap_or(""));
            assert!(it.next().is_none(), "extra tokens in TYPE line: {line}");
            assert!(name.starts_with("h2opus_"), "unprefixed TYPE: {line}");
            assert!(
                ty == "counter" || ty == "gauge" || ty == "histogram",
                "unknown type in: {line}"
            );
        } else {
            check_sample_line(line);
            samples += 1;
        }
    }
    assert!(samples > 20, "suspiciously few samples: {samples}");
    // The recorded histogram must expose its cumulative +Inf bucket.
    assert!(text.contains("h2opus_request_wait_ns_bucket{le=\"+Inf\"}"));
}

#[test]
fn json_snapshot_validates_against_schema() {
    obs::histogram(HistId::PanelExec).record(42_000);
    let text = obs::json_snapshot();
    let doc = h2opus_tlr::runtime::json::parse(&text).expect("snapshot parses");
    let obj = match &doc {
        h2opus_tlr::runtime::json::Json::Obj(o) => o,
        _ => panic!("snapshot is not an object"),
    };
    for key in ["version", "schema", "phases", "kernels", "batch", "serve", "shards",
        "histograms"]
    {
        assert!(obj.contains_key(key), "missing top-level key {key}");
    }
}

// ------------------------------------------ sharded request timelines

#[test]
fn sharded_run_reconstructs_full_request_timelines() {
    let f = small_factor(0x5AD);
    let n = 128;
    let dir = temp_dir("sharded_timeline");
    let store = FactorStore::open(&dir).unwrap();
    let (key_a, key_b) = (7u64, 9u64);
    store.save_chol(key_a, &f, "obs timeline A").unwrap();
    store.save_chol(key_b, &f, "obs timeline B").unwrap();
    let map = ShardMap::new(8, vec!["w0".to_string(), "w1".to_string()]);
    let service = ShardedService::start_with_map(
        &FactorStore::open(&dir).unwrap(),
        ServeOpts {
            max_panel: 8,
            flush_deadline: Duration::from_millis(3),
            ..Default::default()
        },
        map,
    )
    .unwrap();
    let mut rng = Rng::new(0x71E);
    let tickets: Vec<_> = (0..24usize)
        .map(|i| {
            let key = if i % 2 == 0 { key_a } else { key_b };
            let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            service.submit(key, rhs).unwrap()
        })
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    // Reconstruct timelines from the global ring: group by request id.
    let events = obs::recorder().events();
    assert!(events.len() <= obs::RING_CAPACITY);
    let mut by_req: std::collections::BTreeMap<u64, Vec<&obs::Event>> =
        std::collections::BTreeMap::new();
    for e in &events {
        if e.req != 0 {
            by_req.entry(e.req).or_default().push(e);
        }
    }
    let want = ["submitted", "enqueued", "coalesced", "executed", "responded"];
    let full = by_req.values().filter(|tl| {
        let mut next = 0;
        for e in tl.iter() {
            if next < want.len() && e.kind.name() == want[next] {
                next += 1;
            }
        }
        // events() sorts by seq, so per-request order is seq order; a
        // full timeline also has strictly increasing seqs by that sort.
        next == want.len() && tl.windows(2).all(|w| w[0].seq < w[1].seq)
    });
    assert!(
        full.count() >= 1,
        "no request left a complete timeline among {} traced requests",
        by_req.len()
    );
    // Per-key fleet-merged latency is visible through the front end.
    for key in [key_a, key_b] {
        let kh = service.key_hists(key).expect("fleet key histograms");
        assert!(kh.wait.bucket_total() >= 12, "key {key}: {}", kh.wait.bucket_total());
        assert!(!kh.exec.percentile(0.95).is_nan());
    }
    let _ = std::fs::remove_dir_all(&dir);
}
