//! Dispatch-pin verification (tier 1 of `docs/verification.md`).
//!
//! The SIMD microkernels are `unsafe` `#[target_feature]` functions
//! that are sound only after `simd::active()` has confirmed the CPU
//! feature (or pinned the scalar fallback). `active()` caches its
//! answer in a `OnceLock`, so the `H2OPUS_FORCE_SCALAR` pin cannot be
//! toggled inside one process — the test re-executes **itself** as a
//! child process with the pin set and verifies, via the kernel
//! dispatch counters, that no vector kernel slot sees a single call on
//! either the f64 or the mixed-precision path.

use h2opus_tlr::batch::{NativeBatch, RefBatch, StreamBuilder};
use h2opus_tlr::linalg::simd::{active, Kernel};
use h2opus_tlr::linalg::{MatrixF32, Rng, Trans};
use h2opus_tlr::profile::{self, KernelReport, KERNEL_NAMES};
use h2opus_tlr::Matrix;

/// Role marker: set (by the parent test) when this process is the
/// re-executed child that must observe the scalar pin.
const ROLE_ENV: &str = "H2OPUS_VERIFY_ROLE";

/// Drive a small op-stream with both a mixed-precision (f32 B operand)
/// GEMM and a plain-f64 GEMM through the native executor, returning the
/// kernel-counter delta plus the native and oracle outputs.
fn run_mixed_plan() -> (KernelReport, Vec<Matrix>, Vec<Matrix>) {
    let mut rng = Rng::new(0xD15);
    let a = rng.normal_matrix(48, 32);
    let b32 = MatrixF32::from_f64(&rng.normal_matrix(32, 24));
    let c = rng.normal_matrix(48, 24);
    let e = rng.normal_matrix(24, 24);
    let mut sb = StreamBuilder::new();
    let ar = sb.input(&a);
    let br = sb.input32(&b32);
    let cr = sb.input(&c);
    let er = sb.input(&e);
    let d0 = sb.output(48, 24);
    sb.gemm(Trans::No, Trans::No, 1.0, ar, br, 0.0, d0); // mixed kernel path
    let d1 = sb.output(48, 24);
    sb.gemm(Trans::No, Trans::No, -0.5, cr, er, 0.0, d1); // f64 kernel path
    let stream = sb.finish();
    stream.plan().assert_valid();
    let before = profile::kernel_snapshot();
    let native = stream.execute(&NativeBatch::new());
    let delta = profile::kernel_snapshot().since(&before);
    let oracle = stream.execute(&RefBatch);
    (delta, native, oracle)
}

/// Child half: only meaningful when the parent re-executed us with
/// `H2OPUS_FORCE_SCALAR=1`. Asserts the pin is consulted before any
/// `#[target_feature]` kernel can run — every call (f64 and mixed)
/// lands in the scalar slot — and that the scalar mixed path matches
/// the widening oracle.
#[test]
fn child_scalar_dispatch_pin() {
    if std::env::var_os(ROLE_ENV).is_none() {
        return; // direct run: the parent test below drives this
    }
    assert_eq!(active(), Kernel::Scalar, "H2OPUS_FORCE_SCALAR must pin dispatch to scalar");
    let (delta, native, oracle) = run_mixed_plan();
    let scalar = Kernel::Scalar.index();
    assert!(delta.mixed_calls[scalar] > 0, "mixed path must have run: {delta:?}");
    assert!(delta.f64_calls[scalar] > 0, "f64 path must have run: {delta:?}");
    for (k, name) in KERNEL_NAMES.iter().enumerate() {
        if k == scalar {
            continue;
        }
        assert_eq!(
            delta.f64_calls[k] + delta.mixed_calls[k],
            0,
            "kernel slot `{name}` was reached despite the scalar pin"
        );
    }
    for (n, o) in native.iter().zip(&oracle) {
        let scale = n.norm_max().max(o.norm_max()).max(1.0);
        assert!(n.sub(o).norm_max() <= 1e-13 * scale, "scalar mixed result off the oracle");
    }
    println!("CHILD_SCALAR_PIN_OK");
}

/// Parent half: re-execute this test binary with the scalar pin set
/// and require the child assertions to pass. `active()`'s `OnceLock`
/// caching is exactly why this needs a fresh process.
#[test]
fn force_scalar_pin_is_consulted_before_target_feature_kernels() {
    if std::env::var_os(ROLE_ENV).is_some() {
        return; // we *are* the child; don't recurse
    }
    let exe = std::env::current_exe().expect("test binary path");
    let out = std::process::Command::new(exe)
        .args(["child_scalar_dispatch_pin", "--exact", "--nocapture"])
        .env(ROLE_ENV, "child")
        .env("H2OPUS_FORCE_SCALAR", "1")
        .output()
        .expect("child test process must spawn");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(out.status.success(), "child run failed:\n{text}");
    assert!(text.contains("CHILD_SCALAR_PIN_OK"), "child skipped the pin check:\n{text}");
}
