//! Property tests for the non-uniform batched-GEMM op-stream
//! (`batch::gemm_batch`). The oracle property runs on the in-tree
//! proptest runner ([`h2opus_tlr::testing`]): plan specs shrink toward
//! smaller f64-only plans, and failing seeds pin into
//! `proptest-regressions/batch_plan.txt`.
//!
//! Properties:
//! * any randomly generated `BatchPlan` — including mixed-precision
//!   plans with f32-stored operands — executed by the parallel
//!   `NativeBatch` matches the serial naive-oracle `RefBatch` (which
//!   widens f32 exactly) to 1e-13 (relative);
//! * wave grouping never reorders dependent ops (RAW/WAR/WAW pairs land
//!   in strictly increasing waves, ops within a wave keep program
//!   order);
//! * the fused `sample_chain` lowering agrees with the hand-computed
//!   Eq-2/Eq-3 product chain across a batch of variable-shape terms.

use h2opus_tlr::batch::{Arg, BatchOp, NativeBatch, RefBatch, SampleChain, StreamBuilder};
use h2opus_tlr::linalg::gemm::{matmul, matmul_tn, Trans};
use h2opus_tlr::linalg::matrix32::MatrixF32;
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::testing::proptest::{no_panic, run_prop, Strategy};
use h2opus_tlr::Matrix;

/// Pinned counterexample seeds, replayed before any fresh generation.
const REGRESSIONS: &str = include_str!("proptest-regressions/batch_plan.txt");

const SEEDS: std::ops::Range<u64> = 0..24;

/// Symbolic operand: a fresh input of the given shape (f64- or
/// f32-stored), or an existing output slot (creates a dependency edge).
enum Operand {
    NewInput(usize, usize),
    NewInput32(usize, usize),
    Existing(usize),
}

/// Symbolic op description, materialized into a real stream later.
struct OpDesc {
    ta: Trans,
    tb: Trans,
    alpha: f64,
    beta: f64,
    a: Operand,
    b: Operand,
    dst: usize,
}

enum StepDesc {
    Gemm(OpDesc),
    Scale { dst: usize, d: Vec<f64> },
}

/// Generate a random valid stream description: random shapes, random
/// transposes, slot reuse for accumulation chains, operand reuse for
/// read-after-write chains, occasional row scalings. With `mixed`,
/// roughly a third of fresh operands are f32-stored, exercising the
/// widening mixed-precision kernel paths.
fn random_description_with(
    rng: &mut Rng,
    mixed: bool,
    max_ops: usize,
) -> (Vec<(usize, usize)>, Vec<StepDesc>) {
    let n_ops = 1 + rng.below(max_ops);
    let mut out_shapes: Vec<(usize, usize)> = Vec::new();
    let mut steps: Vec<StepDesc> = Vec::new();
    let dim = |rng: &mut Rng| 1 + rng.below(12);
    for _ in 0..n_ops {
        if !out_shapes.is_empty() && rng.uniform() < 0.15 {
            let dst = rng.below(out_shapes.len());
            let d: Vec<f64> = (0..out_shapes[dst].0).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
            steps.push(StepDesc::Scale { dst, d });
            continue;
        }
        // Destination: reuse an existing slot (accumulate/overwrite) or
        // make a new one.
        let dst = if !out_shapes.is_empty() && rng.uniform() < 0.3 {
            rng.below(out_shapes.len())
        } else {
            out_shapes.push((dim(rng), dim(rng)));
            out_shapes.len() - 1
        };
        let (m, n) = out_shapes[dst];
        let k = dim(rng);
        let ta = if rng.below(2) == 0 { Trans::No } else { Trans::Yes };
        let tb = if rng.below(2) == 0 { Trans::No } else { Trans::Yes };
        let a_shape = if ta == Trans::No { (m, k) } else { (k, m) };
        let b_shape = if tb == Trans::No { (k, n) } else { (n, k) };
        let pick = |rng: &mut Rng, shape: (usize, usize), out_shapes: &[(usize, usize)]| {
            if rng.uniform() < 0.35 {
                // Reuse an output slot of exactly this shape (not dst).
                let candidates: Vec<usize> = out_shapes
                    .iter()
                    .enumerate()
                    .filter(|&(s, &sh)| sh == shape && s != dst)
                    .map(|(s, _)| s)
                    .collect();
                if !candidates.is_empty() {
                    return Operand::Existing(candidates[rng.below(candidates.len())]);
                }
            }
            if mixed && rng.uniform() < 0.35 {
                Operand::NewInput32(shape.0, shape.1)
            } else {
                Operand::NewInput(shape.0, shape.1)
            }
        };
        let a = pick(rng, a_shape, &out_shapes);
        let b = pick(rng, b_shape, &out_shapes);
        let alpha = rng.uniform_in(-2.0, 2.0);
        let beta = match rng.below(3) {
            0 => 0.0,
            1 => 1.0,
            _ => rng.uniform_in(-1.0, 1.0),
        };
        steps.push(StepDesc::Gemm(OpDesc { ta, tb, alpha, beta, a, b, dst }));
    }
    (out_shapes, steps)
}

fn random_description(rng: &mut Rng) -> (Vec<(usize, usize)>, Vec<StepDesc>) {
    random_description_with(rng, false, 36)
}

/// Materialize the description: allocate input matrices (f64 and
/// f32-stored in description order), build the stream, and return it
/// alongside its backing storage.
fn build_inputs(rng: &mut Rng, steps: &[StepDesc]) -> (Vec<Matrix>, Vec<MatrixF32>) {
    let mut inputs = Vec::new();
    let mut inputs32 = Vec::new();
    for step in steps {
        if let StepDesc::Gemm(g) = step {
            for op in [&g.a, &g.b] {
                match op {
                    Operand::NewInput(r, c) => inputs.push(rng.normal_matrix(*r, *c)),
                    Operand::NewInput32(r, c) => {
                        inputs32.push(MatrixF32::from_f64(&rng.normal_matrix(*r, *c)))
                    }
                    Operand::Existing(_) => {}
                }
            }
        }
    }
    (inputs, inputs32)
}

fn build_stream<'a>(
    out_shapes: &[(usize, usize)],
    steps: &'a [StepDesc],
    inputs: &'a [Matrix],
    inputs32: &'a [MatrixF32],
) -> h2opus_tlr::batch::GemmStream<'a> {
    let mut sb = StreamBuilder::new();
    let slots: Vec<usize> = out_shapes.iter().map(|&(r, c)| sb.output(r, c)).collect();
    let mut next_input = 0;
    let mut next_input32 = 0;
    for step in steps {
        match step {
            StepDesc::Gemm(g) => {
                let mut resolve = |op: &Operand| match op {
                    Operand::NewInput(..) => {
                        let arg = sb.input(&inputs[next_input]);
                        next_input += 1;
                        arg
                    }
                    Operand::NewInput32(..) => {
                        let arg = sb.input32(&inputs32[next_input32]);
                        next_input32 += 1;
                        arg
                    }
                    Operand::Existing(s) => Arg::Out(slots[*s]),
                };
                let a = resolve(&g.a);
                let b = resolve(&g.b);
                sb.gemm(g.ta, g.tb, g.alpha, a, b, g.beta, slots[g.dst]);
            }
            StepDesc::Scale { dst, d } => sb.scale_rows(slots[*dst], d),
        }
    }
    sb.finish()
}

fn assert_close(a: &Matrix, b: &Matrix, tol: f64, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    let scale = a.norm_max().max(b.norm_max()).max(1.0);
    let diff = a.sub(b).norm_max();
    assert!(diff <= tol * scale, "{ctx}: diff {diff} > {tol} * {scale}");
}

/// A whole plan scenario for the proptest runner: the plan is rebuilt
/// from `seed` inside the property. Shrinks toward smaller, f64-only
/// plans (a smaller `max_ops` regenerates a smaller plan from the
/// same seed — not a sub-plan, but usually still failing when the bug
/// is generic).
#[derive(Clone, Debug)]
struct PlanSpec {
    seed: u64,
    mixed: bool,
    max_ops: usize,
}

struct PlanSpecStrategy;
impl Strategy for PlanSpecStrategy {
    type Value = PlanSpec;
    fn generate(&self, rng: &mut Rng) -> PlanSpec {
        PlanSpec { seed: rng.next_u64(), mixed: rng.uniform() < 0.6, max_ops: 36 }
    }
    fn shrink(&self, v: &PlanSpec) -> Vec<PlanSpec> {
        let mut out = Vec::new();
        if v.mixed {
            out.push(PlanSpec { mixed: false, ..v.clone() });
        }
        if v.max_ops > 1 {
            out.push(PlanSpec { max_ops: v.max_ops / 2, ..v.clone() });
            out.push(PlanSpec { max_ops: 1, ..v.clone() });
        }
        out
    }
}

/// The tier-1 oracle property: any plan — mixed-precision included —
/// executes identically (to f64 roundoff) on the parallel native
/// executor and the serial widening oracle.
#[test]
fn prop_native_matches_oracle_on_random_plans() {
    run_prop("native_vs_oracle", REGRESSIONS, &PlanSpecStrategy, |spec| {
        let mut rng = Rng::new(spec.seed);
        let (out_shapes, steps) = random_description_with(&mut rng, spec.mixed, spec.max_ops);
        let (inputs, inputs32) = build_inputs(&mut rng, &steps);
        let stream = build_stream(&out_shapes, &steps, &inputs, &inputs32);
        no_panic("plan validity", || stream.plan().assert_valid())?;
        let native = stream.execute(&NativeBatch::new());
        let oracle = stream.execute(&RefBatch);
        if native.len() != oracle.len() {
            return Err(format!("slot counts differ: {} vs {}", native.len(), oracle.len()));
        }
        for (s, (nv, ov)) in native.iter().zip(&oracle).enumerate() {
            no_panic("native/oracle compare", || assert_close(nv, ov, 1e-13, &format!("slot={s}")))?;
        }
        Ok(())
    });
}

#[test]
fn prop_waves_never_reorder_dependent_ops() {
    for seed in SEEDS {
        let mut rng = Rng::new(0x3A7E5 + seed);
        let (out_shapes, steps) = random_description(&mut rng);
        let (inputs, inputs32) = build_inputs(&mut rng, &steps);
        let stream = build_stream(&out_shapes, &steps, &inputs, &inputs32);
        let plan = stream.plan();
        // The plan's own invariant check re-derives RAW/WAR/WAW edges.
        plan.assert_valid();
        // Waves keep program order internally, and dependent pairs land
        // in strictly increasing waves (re-derived here independently).
        let mut wave_of = vec![usize::MAX; plan.ops().len()];
        for (w, wave) in plan.waves().iter().enumerate() {
            let ordered = wave.windows(2).all(|p| p[0] < p[1]);
            assert!(ordered, "seed={seed}: wave {w} not in program order");
            for &op in wave {
                wave_of[op] = w;
            }
        }
        let writes = |op: &BatchOp| match op {
            BatchOp::Gemm(g) => g.dst,
            BatchOp::ScaleRows { dst, .. } => *dst,
        };
        let reads = |op: &BatchOp| -> Vec<usize> {
            let mut r = Vec::new();
            if let BatchOp::Gemm(g) = op {
                for arg in [g.a, g.b] {
                    if let Arg::Out(s) = arg {
                        r.push(s);
                    }
                }
                if g.beta != 0.0 {
                    r.push(g.dst);
                }
            } else {
                r.push(writes(op));
            }
            r
        };
        for i in 0..plan.ops().len() {
            for j in 0..i {
                let (oi, oj) = (&plan.ops()[i], &plan.ops()[j]);
                let dependent = reads(oi).contains(&writes(oj))
                    || writes(oi) == writes(oj)
                    || reads(oj).contains(&writes(oi));
                if dependent {
                    assert!(
                        wave_of[j] < wave_of[i],
                        "seed={seed}: dependent ops {j}->{i} in waves {} vs {}",
                        wave_of[j],
                        wave_of[i]
                    );
                }
            }
        }
    }
}

#[test]
fn prop_fused_chain_batch_matches_manual() {
    // A batch of variable-shape Eq-2/Eq-3 terms accumulated into
    // per-tile outputs — the exact workload `batched_ara` issues.
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xC4A1 + seed);
        let n_tiles = 1 + rng.below(6);
        struct Term {
            uk: Matrix,
            vk: Matrix,
            ui: Matrix,
            vi: Matrix,
            d: Option<Vec<f64>>,
        }
        let mut omegas = Vec::new();
        let mut tiles: Vec<Vec<Term>> = Vec::new();
        for _ in 0..n_tiles {
            let m_k = 2 + rng.below(10);
            let m_i = 2 + rng.below(10);
            let m_j = 2 + rng.below(10);
            let bs = 1 + rng.below(4);
            omegas.push(rng.normal_matrix(m_k, bs));
            let n_terms = rng.below(4);
            let terms = (0..n_terms)
                .map(|_| {
                    let r1 = 1 + rng.below(4);
                    let r2 = 1 + rng.below(4);
                    Term {
                        uk: rng.normal_matrix(m_k, r1),
                        vk: rng.normal_matrix(m_j, r1),
                        ui: rng.normal_matrix(m_i, r2),
                        vi: rng.normal_matrix(m_j, r2),
                        d: if rng.below(2) == 0 {
                            Some((0..m_j).map(|_| rng.uniform_in(0.5, 2.0)).collect())
                        } else {
                            None
                        },
                    }
                })
                .collect();
            tiles.push(terms);
        }
        let mut sb = StreamBuilder::new();
        let mut slots = Vec::new();
        for (t, terms) in tiles.iter().enumerate() {
            let om = sb.input(&omegas[t]);
            let rows = terms.first().map(|x| x.ui.rows()).unwrap_or(3);
            let dst = sb.output(rows, omegas[t].cols());
            slots.push(dst);
            for term in terms {
                sb.sample_chain(
                    &SampleChain {
                        uk: (&term.uk).into(),
                        vk: (&term.vk).into(),
                        ui: (&term.ui).into(),
                        vi: (&term.vi).into(),
                        d: term.d.as_deref(),
                        omega: om,
                    },
                    -1.0,
                    dst,
                );
            }
        }
        let stream = sb.finish();
        stream.plan().assert_valid();
        let native = stream.execute(&NativeBatch::new());
        let oracle = stream.execute(&RefBatch);
        for (t, terms) in tiles.iter().enumerate() {
            // Manual chain per tile.
            let rows = terms.first().map(|x| x.ui.rows()).unwrap_or(3);
            let mut expect = Matrix::zeros(rows, omegas[t].cols());
            for term in terms {
                let mut t2 = matmul(&term.vk, &matmul_tn(&term.uk, &omegas[t]));
                if let Some(d) = &term.d {
                    for j in 0..t2.cols() {
                        for i in 0..t2.rows() {
                            t2[(i, j)] *= d[i];
                        }
                    }
                }
                expect.axpy(-1.0, &matmul(&term.ui, &matmul_tn(&term.vi, &t2)));
            }
            assert_close(&native[slots[t]], &expect, 1e-12, &format!("seed={seed} tile={t}"));
            let ctx = format!("seed={seed} tile={t} oracle");
            assert_close(&oracle[slots[t]], &expect, 1e-12, &ctx);
        }
    }
}
