"""L2 JAX graphs (model.py): the batched steps the rust coordinator
executes, including the fused scan-based panel sampler."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

from .conftest import make_batch


def test_sample_step_is_tuple_wrapped(rng):
    d = make_batch(rng, 2, 16, 4, 4)
    out = model.sample_step(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
    assert isinstance(out, tuple) and len(out) == 1
    want = ref.sample_update_ref(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
    assert_allclose(np.asarray(out[0]), np.asarray(want), rtol=1e-12)


def test_sample_step_ldl(rng):
    d = make_batch(rng, 2, 16, 4, 4)
    out = model.sample_step_ldl(
        d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
    )
    want = ref.sample_update_ldl_ref(
        d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
    )
    assert_allclose(np.asarray(out[0]), np.asarray(want), rtol=1e-12)


def test_tile_apply(rng):
    d = make_batch(rng, 2, 16, 4, 4)
    out = model.tile_apply(d["uk"], d["vk"], d["omega"], d["yacc"])
    want = ref.lr_apply_ref(d["uk"], d["vk"], d["omega"], d["yacc"])
    assert_allclose(np.asarray(out[0]), np.asarray(want), rtol=1e-12)


def test_panel_sample_matches_ref(rng):
    j, b, m, kk, bs = 3, 2, 16, 4, 4
    stacked = {
        key: rng.standard_normal((j, b, m, kk)) for key in ("uks", "vks", "uis", "vis")
    }
    aik_u = rng.standard_normal((b, m, kk))
    aik_v = rng.standard_normal((b, m, kk))
    omega = rng.standard_normal((b, m, bs))
    out = model.panel_sample(
        stacked["uks"], stacked["vks"], stacked["uis"], stacked["vis"], aik_u, aik_v, omega
    )
    want = ref.panel_sample_ref(
        stacked["uks"], stacked["vks"], stacked["uis"], stacked["vis"], aik_u, aik_v, omega
    )
    assert_allclose(np.asarray(out[0]), np.asarray(want), rtol=1e-11, atol=1e-11)


def test_panel_sample_scan_equals_manual_loop(rng):
    # The lax.scan fusion must agree with a hand-rolled python loop over
    # the update terms.
    j, b, m, kk, bs = 4, 1, 8, 3, 2
    uks = rng.standard_normal((j, b, m, kk))
    vks = rng.standard_normal((j, b, m, kk))
    uis = rng.standard_normal((j, b, m, kk))
    vis = rng.standard_normal((j, b, m, kk))
    aik_u = rng.standard_normal((b, m, kk))
    aik_v = rng.standard_normal((b, m, kk))
    omega = rng.standard_normal((b, m, bs))
    (got,) = model.panel_sample(uks, vks, uis, vis, aik_u, aik_v, omega)

    manual = aik_u[0] @ (aik_v[0].T @ omega[0])
    for t in range(j):
        manual = manual - uis[t, 0] @ (vis[t, 0].T @ (vks[t, 0] @ (uks[t, 0].T @ omega[0])))
    assert_allclose(np.asarray(got[0]), manual, rtol=1e-11, atol=1e-11)


def test_graphs_are_jittable(rng):
    # The AOT path jits these; make sure nothing relies on python side
    # effects at trace time.
    d = make_batch(rng, 2, 16, 4, 4)
    jitted = jax.jit(model.sample_step)
    (a,) = jitted(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
    (b_,) = model.sample_step(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
    assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-12)


def test_float64_enabled():
    # aot.py lowers f64 artifacts; the x64 flag must be active under test.
    assert jnp.zeros(1).dtype == jnp.float32 or jax.config.jax_enable_x64
    assert np.asarray(jnp.array([1.0], dtype=jnp.float64)).dtype == np.float64
