"""AOT lowering path (aot.py): HLO text generation and the manifest
contract the rust runtime (rust/src/runtime/) depends on."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref

from .conftest import make_batch


def test_lower_variant_produces_hlo_text():
    text = aot.lower_variant("sample_update", 2, 16, 4, 4)
    assert "HloModule" in text
    # f64 inputs of the right shapes appear in the entry computation.
    assert "f64[2,16,4]" in text
    assert "f64[2,16,4]" in text.replace(" ", "")


def test_lower_all_ops():
    for op in ["sample_update", "sample_update_ldl", "tile_apply"]:
        text = aot.lower_variant(op, 2, 8, 2, 2)
        assert "HloModule" in text, op


def test_lower_panel():
    text = aot.lower_panel(2, 8, 2, 2, 3)
    assert "HloModule" in text


def test_lower_rejects_unknown_op():
    with pytest.raises(ValueError):
        aot.lower_variant("nonsense", 1, 8, 2, 2)


def test_variant_table_matches_manifest_schema():
    for v in aot.VARIANTS:
        op, b, m, k, bs = v
        assert op in {"sample_update", "sample_update_ldl", "tile_apply"}
        assert all(isinstance(x, int) and x > 0 for x in (b, m, k, bs))
        assert k <= m, "rank cap must not exceed tile size"


def test_artifacts_dir_consistent_with_manifest():
    # When `make artifacts` has run, every manifest entry must exist and
    # carry the fields the rust loader parses.
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        manifest = json.load(f)
    assert manifest, "manifest must not be empty"
    for entry in manifest:
        for key in ("name", "file", "op", "b", "m", "k", "bs"):
            assert key in entry, entry
        path = os.path.join(art, entry["file"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, path


def test_roundtrip_through_xla_computation(rng):
    """Lower through the exact aot.py path (stablehlo -> XlaComputation ->
    HLO text), check the text is what the rust loader parses, and check
    the computation the text came from produces oracle-correct numbers
    when the same jitted function executes.

    (Executing the *text* itself happens on the rust side —
    rust/tests/pjrt_roundtrip.rs — because xla_extension 0.5.1 is the
    component that must parse it.)"""
    import jax

    b, m, k, bs = 2, 16, 4, 4
    d = make_batch(rng, b, m, k, bs)
    args = [d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"]]
    lowered = jax.jit(model.sample_step).lower(*args)
    text = aot.to_hlo_text(lowered)
    # Structural contract the rust loader depends on.
    assert text.lstrip().startswith("HloModule")
    assert "ENTRY" in text
    assert f"f64[{b},{m},{k}]" in text
    assert f"f64[{b},{m},{bs}]" in text
    # The same lowered computation executes to oracle-correct numbers.
    (got,) = jax.jit(model.sample_step)(*args)
    want = ref.sample_update_ref(*args)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-11, atol=1e-11)
