"""Shared pytest fixtures for the kernel/model/AOT test suites."""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def make_batch(rng, b, m, k, bs, dtype=np.float64):
    """Random factor/omega/yacc batch for the sampling chains."""
    return {
        "uk": rng.standard_normal((b, m, k)).astype(dtype),
        "vk": rng.standard_normal((b, m, k)).astype(dtype),
        "ui": rng.standard_normal((b, m, k)).astype(dtype),
        "vi": rng.standard_normal((b, m, k)).astype(dtype),
        "d": rng.standard_normal((b, m)).astype(dtype),
        "omega": rng.standard_normal((b, m, bs)).astype(dtype),
        "yacc": rng.standard_normal((b, m, bs)).astype(dtype),
    }
