"""L1 Pallas kernels vs the pure-jnp oracle (ref.py) — the core
correctness signal for everything the rust runtime later executes.

Hypothesis sweeps shapes and dtypes; fixed tests pin the exact AOT
variant shapes and the padding contract.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import sample as k

from .conftest import make_batch


def _np(x):
    return np.asarray(x)


class TestSampleUpdate:
    def test_matches_ref_fixed(self, rng):
        b = make_batch(rng, 4, 32, 8, 8)
        got = k.sample_update(b["uk"], b["vk"], b["ui"], b["vi"], b["omega"], b["yacc"])
        want = ref.sample_update_ref(b["uk"], b["vk"], b["ui"], b["vi"], b["omega"], b["yacc"])
        assert_allclose(_np(got), _np(want), rtol=1e-12, atol=1e-12)

    def test_matches_dense_composition(self, rng):
        # Independent oracle: materialize the low-rank products densely.
        b, m, kk, bs = 2, 16, 4, 4
        d = make_batch(rng, b, m, kk, bs)
        got = _np(k.sample_update(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"]))
        for t in range(b):
            lkj = d["uk"][t] @ d["vk"][t].T  # L(k,j) = U V^T
            lij = d["ui"][t] @ d["vi"][t].T
            want = d["yacc"][t] + lij @ lkj.T @ d["omega"][t]
            assert_allclose(got[t], want, rtol=1e-10, atol=1e-10)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 5),
        m=st.sampled_from([8, 16, 33, 64]),
        kk=st.integers(1, 16),
        bs=st.sampled_from([1, 4, 8]),
    )
    def test_shape_sweep(self, b, m, kk, bs):
        rng = np.random.default_rng(b * 1000 + m * 10 + kk + bs)
        d = make_batch(rng, b, m, kk, bs)
        got = k.sample_update(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        want = ref.sample_update_ref(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        assert got.shape == (b, m, bs)
        assert_allclose(_np(got), _np(want), rtol=1e-11, atol=1e-11)

    @pytest.mark.parametrize("dtype,tol", [(np.float32, 1e-5), (np.float64, 1e-12)])
    def test_dtypes(self, rng, dtype, tol):
        d = make_batch(rng, 2, 16, 4, 4, dtype=dtype)
        got = k.sample_update(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        want = ref.sample_update_ref(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        assert _np(got).dtype == dtype
        assert_allclose(_np(got), _np(want), rtol=tol, atol=tol)

    def test_zero_padding_is_exact(self, rng):
        # The DESIGN.md §6 contract: padding factor columns with zeros
        # must not change the result.
        b, m, kk, bs, kpad = 3, 16, 5, 4, 11
        d = make_batch(rng, b, m, kk, bs)
        padded = {
            key: np.concatenate([d[key], np.zeros((b, m, kpad - kk))], axis=2)
            for key in ("uk", "vk", "ui", "vi")
        }
        got = k.sample_update(
            padded["uk"], padded["vk"], padded["ui"], padded["vi"], d["omega"], d["yacc"]
        )
        want = k.sample_update(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        # Padding adds only zero terms, but changes the contraction
        # blocking — equal to accumulation-order rounding.
        assert_allclose(_np(got), _np(want), rtol=1e-12, atol=1e-12)


class TestSampleUpdateLdl:
    def test_matches_ref(self, rng):
        d = make_batch(rng, 4, 32, 8, 8)
        got = k.sample_update_ldl(
            d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
        )
        want = ref.sample_update_ldl_ref(
            d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
        )
        assert_allclose(_np(got), _np(want), rtol=1e-12, atol=1e-12)

    def test_unit_diagonal_reduces_to_plain(self, rng):
        d = make_batch(rng, 2, 16, 4, 4)
        ones = np.ones_like(d["d"])
        got = k.sample_update_ldl(d["uk"], d["vk"], d["ui"], d["vi"], ones, d["omega"], d["yacc"])
        want = k.sample_update(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        assert_allclose(_np(got), _np(want), rtol=1e-12, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(b=st.integers(1, 4), m=st.sampled_from([8, 24]), kk=st.integers(1, 8))
    def test_shape_sweep(self, b, m, kk):
        rng = np.random.default_rng(b * 100 + m + kk)
        d = make_batch(rng, b, m, kk, 4)
        got = k.sample_update_ldl(
            d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
        )
        want = ref.sample_update_ldl_ref(
            d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
        )
        assert_allclose(_np(got), _np(want), rtol=1e-11, atol=1e-11)


class TestLrApply:
    def test_matches_ref(self, rng):
        d = make_batch(rng, 4, 32, 8, 8)
        got = k.lr_apply(d["uk"], d["vk"], d["omega"], d["yacc"])
        want = ref.lr_apply_ref(d["uk"], d["vk"], d["omega"], d["yacc"])
        assert_allclose(_np(got), _np(want), rtol=1e-12, atol=1e-12)

    @settings(max_examples=10, deadline=None)
    @given(b=st.integers(1, 4), m=st.sampled_from([8, 16, 40]), kk=st.integers(1, 12))
    def test_shape_sweep(self, b, m, kk):
        rng = np.random.default_rng(b + m + kk)
        d = make_batch(rng, b, m, kk, 4)
        got = k.lr_apply(d["uk"], d["vk"], d["omega"], d["yacc"])
        want = ref.lr_apply_ref(d["uk"], d["vk"], d["omega"], d["yacc"])
        assert_allclose(_np(got), _np(want), rtol=1e-11, atol=1e-11)


class TestAotVariantShapes:
    """Pin the exact shapes `aot.py` lowers, so artifact regeneration can
    never drift from what the rust runtime expects."""

    @pytest.mark.parametrize("b,m,kk,bs", [(8, 64, 16, 8), (16, 128, 32, 16)])
    def test_sample_update_variant(self, rng, b, m, kk, bs):
        d = make_batch(rng, b, m, kk, bs)
        got = k.sample_update(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        want = ref.sample_update_ref(d["uk"], d["vk"], d["ui"], d["vi"], d["omega"], d["yacc"])
        assert got.shape == (b, m, bs)
        assert_allclose(_np(got), _np(want), rtol=1e-11, atol=1e-11)

    def test_ldl_variant(self, rng):
        d = make_batch(rng, 8, 64, 16, 8)
        got = k.sample_update_ldl(
            d["uk"], d["vk"], d["ui"], d["vi"], d["d"], d["omega"], d["yacc"]
        )
        assert got.shape == (8, 64, 8)
