"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

HLO *text* is the interchange format (NOT `.serialize()`): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one artifact per (op, B, m, k_max, bs) variant plus manifest.json,
which the rust runtime (rust/src/runtime/) reads to pick the smallest
variant covering a batch.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

DTYPE = jnp.float64

# Variants: kept CI-sized (interpret-mode Pallas on CPU); the same code
# lowers larger (m=512, bs=32) deployment shapes by editing this table.
VARIANTS = [
    # (op, B, m, k_max, bs)
    ("sample_update", 8, 64, 16, 8),
    ("sample_update", 16, 128, 32, 16),
    ("sample_update_ldl", 8, 64, 16, 8),
    ("tile_apply", 8, 64, 16, 8),
    ("tile_apply", 16, 128, 32, 16),
]

# Fused panel variants: (B, m, k_max, bs, J).
PANEL_VARIANTS = [
    (4, 64, 16, 8, 3),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def lower_variant(op, b, m, k, bs):
    fac = spec(b, m, k)
    vec = spec(b, m, bs)
    dia = spec(b, m)
    if op == "sample_update":
        fn, args = model.sample_step, (fac, fac, fac, fac, vec, vec)
    elif op == "sample_update_ldl":
        fn, args = model.sample_step_ldl, (fac, fac, fac, fac, dia, vec, vec)
    elif op == "tile_apply":
        fn, args = model.tile_apply, (fac, fac, vec, vec)
    else:
        raise ValueError(op)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_panel(b, m, k, bs, j):
    fac_j = spec(j, b, m, k)
    fac = spec(b, m, k)
    vec = spec(b, m, bs)
    lowered = jax.jit(model.panel_sample).lower(fac_j, fac_j, fac_j, fac_j, fac, fac, vec)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for op, b, m, k, bs in VARIANTS:
        name = f"{op}_b{b}_m{m}_k{k}_bs{bs}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        text = lower_variant(op, b, m, k, bs)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            {"name": name, "file": name + ".hlo.txt", "op": op, "b": b, "m": m,
             "k": k, "bs": bs}
        )
        print(f"wrote {path} ({len(text)} chars)")
    for b, m, k, bs, j in PANEL_VARIANTS:
        name = f"panel_sample_b{b}_m{m}_k{k}_bs{bs}_j{j}"
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        text = lower_panel(b, m, k, bs, j)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            {"name": name, "file": name + ".hlo.txt", "op": "panel_sample",
             "b": b, "m": m, "k": k, "bs": bs, "j": j}
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
