"""L2: the JAX compute graphs the rust coordinator executes via PJRT.

Each function composes the L1 Pallas kernels into the batched steps of the
left-looking TLR factorization (paper Alg 4/5):

* ``sample_step``     — one batched update term (Eq 2), the unit the rust
                        runtime loops over per (tile, j) pair;
* ``sample_step_ldl`` — the D-interposed LDL^T variant (Eq 3);
* ``tile_apply``      — original-tile term A(i,k) Omega (and TLR matvec
                        tile products, §4.4);
* ``panel_sample``    — the whole Eq 1 expression for a panel: a
                        lax.scan over J stacked update terms fused into a
                        single HLO so XLA schedules the serial chain
                        without host round-trips.

All are shape-monomorphic at lowering time; aot.py emits one artifact per
(m, k_max, bs, B[, J]) variant, and the rust runtime pads ranks up to
k_max (zero columns are exact — DESIGN.md §6 padding contract).
"""

import jax
import jax.numpy as jnp

from .kernels import sample as k


def sample_step(uk, vk, ui, vi, omega, yacc):
    """One batched left-looking update term: Yacc + L(i,j) L(k,j)^T Omega."""
    return (k.sample_update(uk, vk, ui, vi, omega, yacc),)


def sample_step_ldl(uk, vk, ui, vi, d, omega, yacc):
    """LDL^T update term with the diagonal interposed (Eq 3)."""
    return (k.sample_update_ldl(uk, vk, ui, vi, d, omega, yacc),)


def tile_apply(u, v, omega, yacc):
    """Batched low-rank tile application Yacc + U V^T Omega."""
    return (k.lr_apply(u, v, omega, yacc),)


def panel_sample(uks, vks, uis, vis, aik_u, aik_v, omega):
    """Fused Eq 1 sampling: A(i,k) Omega − Σ_j L(i,j) L(k,j)^T Omega.

    uks...: (J, B, m, k) stacked update factors; lax.scan accumulates the
    J serial steps inside one executable.
    """
    zero = jnp.zeros_like(omega)
    y0 = k.lr_apply(aik_u, aik_v, omega, zero)

    def body(acc, term):
        tuk, tvk, tui, tvi = term
        return k.sample_update(tuk, tvk, tui, tvi, omega, acc), None

    acc, _ = jax.lax.scan(body, zero, (uks, vks, uis, vis))
    return (y0 - acc,)
