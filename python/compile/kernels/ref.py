"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels (and, transitively, the AOT
artifacts the rust runtime executes) are validated against in pytest.
Shapes follow the padding contract of DESIGN.md §6: per-tile factors are
zero-padded to a common k_max, which leaves the chain results exact.
"""

import jax.numpy as jnp


def sample_update_ref(uk, vk, ui, vi, omega, yacc):
    """Batched 4-product sampling chain (paper Eq 2).

    Y = Yacc + U_i @ (V_i^T @ (V_k @ (U_k^T @ Omega)))

    Args (batched over the leading dim B):
      uk, vk: (B, m, k)  factors of L(k, j)
      ui, vi: (B, m, k)  factors of L(i, j)
      omega:  (B, m, bs) sampling block
      yacc:   (B, m, bs) running accumulator
    Returns: (B, m, bs)
    """
    t1 = jnp.einsum("bmk,bms->bks", uk, omega)
    t2 = jnp.einsum("bmk,bks->bms", vk, t1)
    t3 = jnp.einsum("bmk,bms->bks", vi, t2)
    return yacc + jnp.einsum("bmk,bks->bms", ui, t3)


def sample_update_ldl_ref(uk, vk, ui, vi, d, omega, yacc):
    """Batched 5-product LDL^T sampling chain (paper Eq 3).

    Y = Yacc + U_i @ (V_i^T @ (D @ (V_k @ (U_k^T @ Omega))))

    d: (B, m) diagonal of D(j, j).
    """
    t1 = jnp.einsum("bmk,bms->bks", uk, omega)
    t2 = jnp.einsum("bmk,bks->bms", vk, t1)
    t2 = d[:, :, None] * t2
    t3 = jnp.einsum("bmk,bms->bks", vi, t2)
    return yacc + jnp.einsum("bmk,bks->bms", ui, t3)


def lr_apply_ref(u, v, omega, yacc):
    """Batched low-rank tile application Y = Yacc + U @ (V^T @ Omega).

    Used for the original-tile term A(i,k) Omega of Eq 1 and for the TLR
    matvec tile products (§4.4).
    """
    t = jnp.einsum("bmk,bms->bks", v, omega)
    return yacc + jnp.einsum("bmk,bks->bms", u, t)


def panel_sample_ref(uks, vks, uis, vis, aik_u, aik_v, omega):
    """Full left-looking panel sampling (paper Eq 1 / Alg 4) for one tile:

    Y = A(i,k) Omega − Σ_j L(i,j) L(k,j)^T Omega

    uks, vks, uis, vis: (J, B, m, k) stacked update-term factors
    aik_u, aik_v:       (B, m, k)    original tile factors
    omega:              (B, m, bs)
    """
    y = lr_apply_ref(aik_u, aik_v, omega, jnp.zeros_like(omega))
    acc = jnp.zeros_like(omega)
    for j in range(uks.shape[0]):
        acc = sample_update_ref(uks[j], vks[j], uis[j], vis[j], omega, acc)
    return y - acc
