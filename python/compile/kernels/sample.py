"""L1 Pallas kernels: the ARA sampling chains of the TLR factorization.

The paper's hot spot is the batched 4-product chain (Eq 2)

    Y += U_i @ (V_i^T @ (V_k @ (U_k^T @ Omega)))

executed for every (tile, update) pair of a panel. On the V100 the paper
uses MAGMA non-uniform batched GEMM; here the same computation is a Pallas
kernel whose grid runs over the batch dimension, with BlockSpec keeping
one tile's factor panels resident in VMEM per grid step (DESIGN.md
§Hardware-Adaptation: VMEM tiling replaces the CUDA threadblock/shared-
memory schedule, and the inner products are MXU-shaped matmuls).

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and the
compile-only TPU lowering is the deployment path.

VMEM budget per grid step (f32, m=512, k=64, bs=32):
  4 factor panels  4*512*64*4B = 0.5 MB
  omega + 2 accum  3*512*32*4B = 0.2 MB          << 16 MB VMEM
leaving ample room for double buffering across grid steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sample_update_kernel(uk_ref, vk_ref, ui_ref, vi_ref, om_ref, yacc_ref, o_ref):
    # One batch element per grid step; refs are (1, m, k) / (1, m, bs).
    uk = uk_ref[0]
    vk = vk_ref[0]
    ui = ui_ref[0]
    vi = vi_ref[0]
    om = om_ref[0]
    t1 = uk.T @ om          # (k, bs)   MXU matmul 1
    t2 = vk @ t1            # (m, bs)   MXU matmul 2
    t3 = vi.T @ t2          # (k, bs)   MXU matmul 3
    o_ref[0] = yacc_ref[0] + ui @ t3  # MXU matmul 4 + accumulate


def _sample_update_ldl_kernel(uk_ref, vk_ref, ui_ref, vi_ref, d_ref, om_ref, yacc_ref, o_ref):
    uk = uk_ref[0]
    vk = vk_ref[0]
    ui = ui_ref[0]
    vi = vi_ref[0]
    d = d_ref[0]
    om = om_ref[0]
    t1 = uk.T @ om
    t2 = d[:, None] * (vk @ t1)   # Eq 3: interpose D(j,j)
    t3 = vi.T @ t2
    o_ref[0] = yacc_ref[0] + ui @ t3


def _lr_apply_kernel(u_ref, v_ref, om_ref, yacc_ref, o_ref):
    u = u_ref[0]
    v = v_ref[0]
    om = om_ref[0]
    t = v.T @ om
    o_ref[0] = yacc_ref[0] + u @ t


def _batched_call(kernel, n_in, b, m, k, bs, dtype, has_diag=False):
    """Build the pallas_call for a batch of B tiles.

    Grid over the batch dim; every operand block is one tile's panel.
    """
    fac = pl.BlockSpec((1, m, k), lambda i: (i, 0, 0))
    vec = pl.BlockSpec((1, m, bs), lambda i: (i, 0, 0))
    dia = pl.BlockSpec((1, m), lambda i: (i, 0))
    if has_diag:
        in_specs = [fac, fac, fac, fac, dia, vec, vec]
    else:
        in_specs = [fac] * (n_in - 2) + [vec, vec]
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=in_specs,
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((b, m, bs), dtype),
        interpret=True,
    )


def sample_update(uk, vk, ui, vi, omega, yacc):
    """Pallas-batched Eq 2 chain. Shapes: see ref.sample_update_ref."""
    b, m, k = uk.shape
    bs = omega.shape[-1]
    call = _batched_call(_sample_update_kernel, 6, b, m, k, bs, uk.dtype)
    return call(uk, vk, ui, vi, omega, yacc)


def sample_update_ldl(uk, vk, ui, vi, d, omega, yacc):
    """Pallas-batched Eq 3 chain (LDL^T: diagonal interposed)."""
    b, m, k = uk.shape
    bs = omega.shape[-1]
    call = _batched_call(_sample_update_ldl_kernel, 7, b, m, k, bs, uk.dtype, has_diag=True)
    return call(uk, vk, ui, vi, d, omega, yacc)


def lr_apply(u, v, omega, yacc):
    """Pallas-batched low-rank tile application (2-product chain)."""
    b, m, k = u.shape
    bs = omega.shape[-1]
    call = _batched_call(_lr_apply_kernel, 4, b, m, k, bs, u.dtype)
    return call(u, v, omega, yacc)


@functools.partial(jax.jit, static_argnames=())
def sample_update_jit(uk, vk, ui, vi, omega, yacc):
    return sample_update(uk, vk, ui, vi, omega, yacc)
