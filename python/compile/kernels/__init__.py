from . import ref, sample  # noqa: F401
