//! Bench: pivoting cost and rank effects — paper §6.3. Compares the
//! unpivoted factorization against Frobenius / power-iteration 2-norm /
//! random pivot selection, and the LDLᵀ variant.
//!
//! Run: `cargo bench --bench pivoting`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{bench_time, instance, rank_stats};
use h2opus_tlr::factor::{cholesky, ldlt, FactorOpts, Pivoting};
use h2opus_tlr::profile::{self, Phase};

fn main() {
    println!("== bench pivoting (paper §6.3) ==");
    let (n, m) = (4096usize, 256usize);
    let inst = instance(Problem::Cov3d, n, m, 1e-6, 18);
    println!("3D covariance N={n} m={m} eps=1e-6:");
    println!(
        "  {:>24} {:>11} {:>11} {:>11} {:>9}",
        "variant", "min (s)", "mean (s)", "pivot (s)", "mean rank"
    );
    for (name, pivot) in [
        ("unpivoted", Pivoting::None),
        ("pivot: Frobenius", Pivoting::Frobenius),
        ("pivot: 2-norm (power)", Pivoting::Norm2),
        ("pivot: random", Pivoting::Random),
    ] {
        let opts = FactorOpts { eps: 1e-6, bs: 16, pivot, ..Default::default() };
        let before = profile::snapshot();
        let mut mean_rank = 0.0;
        let (min, mean) = bench_time(2, || {
            let f = cholesky(inst.tlr.clone(), &opts).expect("factor");
            mean_rank = rank_stats(&f.l).mean;
            std::hint::black_box(&f);
        });
        let prof = profile::snapshot().since(&before);
        // 3 runs recorded (warmup + 2): report per-run pivot cost.
        let pivot_s = prof.nanos[Phase::Pivot as usize] as f64 / 1e9 / 3.0;
        println!("  {name:>24} {min:>11.3} {mean:>11.3} {pivot_s:>11.3} {mean_rank:>9.1}");
    }
    let opts = FactorOpts { eps: 1e-6, bs: 16, ..Default::default() };
    let (min, mean) = bench_time(2, || {
        let f = ldlt(inst.tlr.clone(), &opts).expect("ldlt");
        std::hint::black_box(&f);
    });
    println!("  {:>24} {min:>11.3} {mean:>11.3} {:>11} {:>9}", "LDL^T (unpivoted)", "-", "-");
    println!("(paper: Frobenius selection ~10x cheaper than 2-norm; LDL^T ~ Cholesky)");
}
