//! Bench: looped single-RHS solves vs one blocked multi-RHS solve at
//! several panel widths — the GEMV→GEMM transition the `serve/`
//! subsystem exists to exploit (EXPERIMENTS.md §Multi-RHS).
//!
//! Run: `cargo bench --bench solve_multi`
//!
//! Besides the table, the run records its numbers into
//! `BENCH_solve.json` at the repo root so EXPERIMENTS.md has a stable
//! artifact to cite.

use h2opus_tlr::batch::NativeBatch;
use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{bench_time, instance, kernel_roofline, time_cholesky};
use h2opus_tlr::factor::FactorOpts;
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::obs;
use h2opus_tlr::runtime::json::{to_string, Json};
use h2opus_tlr::serve::store::{load_chol, load_chol_mapped, save_chol};
use h2opus_tlr::serve::{FactorStore, ServeOpts, ShardMap, ShardedService, SolveService};
use h2opus_tlr::solve::{chol_solve, chol_solve_multi_with, solve_flop_estimate};
use std::collections::BTreeMap;
use std::time::Duration;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    println!("== bench solve_multi (serve/: blocked multi-RHS solves) ==");
    // Problem size is env-tunable so CI runners can use a smaller
    // instance (H2OPUS_BENCH_N / H2OPUS_BENCH_M) while local runs keep
    // the paper-scale default.
    let n = env_usize("H2OPUS_BENCH_N", 2048);
    let m = env_usize("H2OPUS_BENCH_M", 128);
    let inst = instance(Problem::Cov2d, n, m, 1e-6, 37);
    let (f, fsecs) = time_cholesky(
        inst.tlr.clone(),
        &FactorOpts { eps: 1e-6, bs: 16, ..Default::default() },
    );
    let mut rng = Rng::new(38);
    let exec = NativeBatch::new();
    println!("cov2d N={n} m={m} eps=1e-6 (factorization {fsecs:.3}s)");
    println!(
        "  {:>6} {:>6} {:>12} {:>12} {:>9} {:>10} {:>10}",
        "r", "reps", "looped (s)", "blocked (s)", "speedup", "cols/s", "GFLOP/s"
    );
    let mut json_rows: Vec<Json> = Vec::new();
    for &w in &[1usize, 4, 16, 64] {
        let b = rng.normal_matrix(n, w);
        let reps = (128 / w).clamp(2, 10);
        // Looped baseline: w independent single-RHS solves.
        let (_, looped) = bench_time(reps, || {
            for j in 0..w {
                std::hint::black_box(chol_solve(&f, b.col(j)));
            }
        });
        // Blocked: one panel solve on a long-lived executor.
        let (_, blocked) = bench_time(reps, || {
            std::hint::black_box(chol_solve_multi_with(&f, &b, &exec));
        });
        let speedup = looped / blocked;
        let cols_per_s = w as f64 / blocked;
        let gflops = solve_flop_estimate(&f.l, w) / blocked / 1e9;
        println!(
            "  {w:>6} {reps:>6} {looped:>12.6} {blocked:>12.6} {speedup:>8.2}x \
             {cols_per_s:>10.1} {gflops:>10.2}"
        );
        let mut row = BTreeMap::new();
        row.insert("width".to_string(), Json::Num(w as f64));
        row.insert("looped_mean_s".to_string(), Json::Num(looped));
        row.insert("blocked_mean_s".to_string(), Json::Num(blocked));
        row.insert("speedup".to_string(), Json::Num(speedup));
        row.insert("cols_per_s".to_string(), Json::Num(cols_per_s));
        row.insert("gflops".to_string(), Json::Num(gflops));
        json_rows.push(Json::Obj(row));
    }
    // -- mmap vs owned factor loading (EXPERIMENTS.md §Zero-copy
    //    loading): persist the factor, then compare a full owned decode
    //    against the zero-copy mapped load, each followed by one
    //    16-wide solve. In-process the page cache is warm, so this
    //    measures the decode/copy overhead the mapped path removes;
    //    cross-process cold numbers need `echo 3 > drop_caches` and are
    //    recorded separately in EXPERIMENTS.md when available.
    let dir = std::env::temp_dir().join(format!("h2opus_bench_store_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let fpath = dir.join("chol.bin");
    save_chol(&fpath, &f).expect("persist factor for load bench");
    let bytes = std::fs::metadata(&fpath).map(|m| m.len()).unwrap_or(0);
    let bw = rng.normal_matrix(n, 16);
    let reps = 5;
    let (_, t_owned) = bench_time(reps, || {
        let lf = load_chol(&fpath).expect("owned load");
        std::hint::black_box(chol_solve_multi_with(&lf, &bw, &exec));
    });
    let (_, t_mmap) = bench_time(reps, || {
        let lf = load_chol_mapped(&fpath).expect("mapped load");
        std::hint::black_box(chol_solve_multi_with(&lf.value, &bw, &exec));
    });
    println!(
        "factor load + 16-wide solve ({bytes} bytes): owned {t_owned:.6}s, \
         mmap {t_mmap:.6}s ({:.2}x)",
        t_owned / t_mmap
    );
    let _ = std::fs::remove_dir_all(&dir);
    let mut load = BTreeMap::new();
    load.insert("factor_bytes".to_string(), Json::Num(bytes as f64));
    load.insert("owned_load_solve_s".to_string(), Json::Num(t_owned));
    load.insert("mmap_load_solve_s".to_string(), Json::Num(t_mmap));
    load.insert("speedup".to_string(), Json::Num(t_owned / t_mmap));

    // -- sharded vs single service (EXPERIMENTS.md §Sharded serving):
    //    the same mixed-key request stream through one SolveService and
    //    through a two-worker ShardedService over the same store. Keys
    //    7 and 9 are pinned to different owners under an 8-shard
    //    two-worker map (see serve::shard's unit tests), so the sharded
    //    run exercises both workers. On a single box this measures the
    //    routing overhead plus whatever parallelism two workers buy;
    //    the cross-host win is capacity (per-worker LRU residency).
    let sdir = std::env::temp_dir().join(format!("h2opus_bench_shard_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&sdir);
    let store = FactorStore::open(&sdir).expect("bench shard store");
    let (key_a, key_b) = (7u64, 9u64);
    store.save_chol(key_a, &f, "bench key A").expect("save A");
    store.save_chol(key_b, &f, "bench key B").expect("save B");
    let requests = env_usize("H2OPUS_BENCH_REQUESTS", 256);
    let opts = ServeOpts {
        max_panel: 16,
        flush_deadline: Duration::from_millis(2),
        ..Default::default()
    };
    // Wait-inclusive wall time: submit the whole mixed-key stream, then
    // drain every ticket.
    fn timed_stream<F>(requests: usize, n: usize, key_a: u64, key_b: u64, submit: F) -> f64
    where
        F: Fn(u64, Vec<f64>) -> h2opus_tlr::serve::Ticket,
    {
        let mut rng = Rng::new(99);
        let rhs: Vec<Vec<f64>> =
            (0..requests).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let t0 = std::time::Instant::now();
        let tickets: Vec<_> = rhs
            .into_iter()
            .enumerate()
            .map(|(i, b)| submit(if i % 2 == 0 { key_a } else { key_b }, b))
            .collect();
        let mut x0 = 0.0;
        for t in tickets {
            x0 += t.wait().expect("answer").x[0];
        }
        std::hint::black_box(x0);
        t0.elapsed().as_secs_f64()
    }
    let single = SolveService::start(FactorStore::open(&sdir).unwrap(), opts.clone());
    let t_single =
        timed_stream(requests, n, key_a, key_b, |k, b| single.submit(k, b).expect("admit"));
    let map = ShardMap::new(8, vec!["w0".to_string(), "w1".to_string()]);
    let sharded = ShardedService::start_with_map(&FactorStore::open(&sdir).unwrap(), opts, map)
        .expect("sharded service");
    let t_sharded =
        timed_stream(requests, n, key_a, key_b, |k, b| sharded.submit(k, b).expect("admit"));
    drop(single);
    drop(sharded);
    let _ = std::fs::remove_dir_all(&sdir);
    let single_rps = requests as f64 / t_single;
    let sharded_rps = requests as f64 / t_sharded;
    println!(
        "sharded serving ({requests} requests, 2 keys): single {single_rps:.1} req/s, \
         2-shard {sharded_rps:.1} req/s ({:.2}x)",
        sharded_rps / single_rps
    );
    let mut shard_obj = BTreeMap::new();
    shard_obj.insert("requests".to_string(), Json::Num(requests as f64));
    shard_obj.insert("keys".to_string(), Json::Num(2.0));
    shard_obj.insert("workers".to_string(), Json::Num(2.0));
    shard_obj.insert("single_rps".to_string(), Json::Num(single_rps));
    shard_obj.insert("sharded_rps".to_string(), Json::Num(sharded_rps));
    shard_obj.insert("speedup".to_string(), Json::Num(sharded_rps / single_rps));

    // -- request latency distribution (obs histograms, fed by the two
    //    service streams above): wait = submit -> panel pickup, exec =
    //    blocked solve. NaN percentiles (empty histogram) become null.
    let pct_or_null = |s: &obs::HistSnapshot, q: f64| {
        let v = s.percentile(q);
        if v.is_nan() { Json::Null } else { Json::Num(v) }
    };
    let mut latency = BTreeMap::new();
    for (name, id) in
        [("wait", obs::HistId::RequestWait), ("exec", obs::HistId::PanelExec)]
    {
        let s = obs::histogram(id).snapshot();
        latency.insert(format!("{name}_count"), Json::Num(s.bucket_total() as f64));
        for (tag, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
            latency.insert(format!("{name}_{tag}_ns"), pct_or_null(&s, q));
        }
    }

    // -- microkernel dispatch (EXPERIMENTS.md §Kernel roofline): one
    //    tile-shaped GEMM through the scalar kernel, the dispatched SIMD
    //    kernel, and the mixed f32-B path, so the solve numbers above
    //    carry a record of which kernel produced them.
    let krows = kernel_roofline(m, m, &[16, 64], 10, 41);
    let kname = krows.first().map(|r| r.kernel_name).unwrap_or("scalar");
    let mut kernel_obj = BTreeMap::new();
    kernel_obj.insert("dispatched".to_string(), Json::Str(kname.to_string()));
    let mut krow_json: Vec<Json> = Vec::new();
    for r in &krows {
        println!(
            "kernel {kname} (m=n={m}, k={}): scalar {:.2} GFLOP/s, {kname} {:.2} ({:.2}x), \
             mixed {:.2} ({:.2}x)",
            r.k,
            r.scalar,
            r.active,
            r.active / r.scalar,
            r.mixed,
            r.mixed / r.scalar
        );
        let mut row = BTreeMap::new();
        row.insert("k".to_string(), Json::Num(r.k as f64));
        row.insert("scalar_gflops".to_string(), Json::Num(r.scalar));
        row.insert("simd_gflops".to_string(), Json::Num(r.active));
        row.insert("mixed_gflops".to_string(), Json::Num(r.mixed));
        row.insert("simd_speedup".to_string(), Json::Num(r.active / r.scalar));
        krow_json.push(Json::Obj(row));
    }
    kernel_obj.insert("shapes".to_string(), Json::Arr(krow_json));

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("solve_multi".to_string()));
    doc.insert("kernel".to_string(), Json::Obj(kernel_obj));
    doc.insert("status".to_string(), Json::Str("measured".to_string()));
    doc.insert("load".to_string(), Json::Obj(load));
    doc.insert("sharded".to_string(), Json::Obj(shard_obj));
    doc.insert("latency".to_string(), Json::Obj(latency));
    doc.insert(
        "problem".to_string(),
        Json::Str(format!("cov2d N={n} m={m} eps=1e-6 seed=37")),
    );
    doc.insert("factor_seconds".to_string(), Json::Num(fsecs));
    doc.insert("widths".to_string(), Json::Arr(json_rows));
    match std::fs::write("BENCH_solve.json", to_string(&Json::Obj(doc))) {
        Ok(()) => println!("wrote BENCH_solve.json"),
        Err(e) => eprintln!("could not write BENCH_solve.json: {e}"),
    }
}
