//! Bench: tile-size sweep — paper Table 1. Memory and factorization time
//! as the tile size doubles, for two problem sizes; the optimum tile size
//! should sit in the interior and grow with N.
//!
//! Run: `cargo bench --bench tile_size`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{bench_time, instance};
use h2opus_tlr::factor::{cholesky, FactorOpts};

fn main() {
    println!("== bench tile_size (paper Table 1) ==");
    for n in [2048usize, 4096] {
        println!("3D covariance N={n}, eps=1e-6:");
        println!(
            "  {:>6} {:>11} {:>11} {:>11} {:>11} {:>11}",
            "tile", "total GB", "dense GB", "LR GB", "min (s)", "mean (s)"
        );
        let mut m = 64;
        while m <= n / 4 {
            let inst = instance(Problem::Cov3d, n, m, 1e-6, 7);
            let mem = inst.tlr.memory();
            let opts = FactorOpts { eps: 1e-6, bs: 16, ..Default::default() };
            let (min, mean) = bench_time(3, || {
                let f = cholesky(inst.tlr.clone(), &opts).expect("factor");
                std::hint::black_box(&f);
            });
            println!(
                "  {m:>6} {:>11.5} {:>11.5} {:>11.5} {min:>11.3} {mean:>11.3}",
                mem.total_gb(),
                mem.dense_gb(),
                mem.lowrank_gb()
            );
            m *= 2;
        }
    }
}
