//! Bench: ARA compression throughput and the dynamic-batching payoff
//! (paper contribution #2). Sweeps the batch capacity to show that the
//! dynamic scheduler keeps the processing batch full when tile ranks are
//! skewed — the mean occupancy and total time improve with capacity while
//! the computed factors stay identical (per-tile RNG streams).
//!
//! Run: `cargo bench --bench ara`

use h2opus_tlr::ara::{batched_ara, AraOpts, DenseSampler, Sampler};
use h2opus_tlr::experiments::bench_time;
use h2opus_tlr::linalg::gemm::matmul_nt;
use h2opus_tlr::linalg::matrix::Matrix;
use h2opus_tlr::linalg::rng::Rng;

/// A skewed batch: many small-rank tiles plus a few large-rank outliers
/// (the paper's statistics-application rank profile).
fn skewed_batch(m: usize, count: usize, seed: u64) -> Vec<Matrix> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            let k = if i % 8 == 0 { m / 2 } else { 4 + (i % 4) * 2 };
            let u = rng.normal_matrix(m, k);
            let v = rng.normal_matrix(m, k);
            matmul_nt(&u, &v)
        })
        .collect()
}

fn main() {
    println!("== bench ara (dynamic batching) ==");
    let m = 256;
    let count = 32;
    let mats = skewed_batch(m, count, 1);
    let samplers: Vec<DenseSampler> = mats.iter().map(DenseSampler).collect();
    let ops: Vec<&dyn Sampler> = samplers.iter().map(|s| s as &dyn Sampler).collect();
    let prios: Vec<usize> = mats.iter().map(|a| a.rows()).collect();
    let opts = AraOpts::new(16, 1e-9);
    println!("{count} tiles of {m}x{m}, skewed ranks (4..{}), bs=16, eps=1e-9:", m / 2);
    println!(
        "  {:>9} {:>11} {:>11} {:>10} {:>8}",
        "capacity", "min (s)", "mean (s)", "occupancy", "rounds"
    );
    for capacity in [1usize, 2, 4, 8, 16, 32] {
        let mut occ = 0.0;
        let mut rounds = 0;
        let (min, mean) = bench_time(3, || {
            let out = batched_ara(&ops, &prios, capacity, &opts, 77);
            occ = out.stats.mean_occupancy();
            rounds = out.stats.rounds;
            std::hint::black_box(&out);
        });
        println!("  {capacity:>9} {min:>11.4} {mean:>11.4} {occ:>10.2} {rounds:>8}");
    }
    println!("(expected: occupancy ~= capacity until the tile pool is exhausted;");
    println!(" wall time falls as the batch keeps every worker fed)");
}
