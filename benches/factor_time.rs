//! Bench: TLR Cholesky factorization time vs N and ε, with the dense
//! Cholesky baseline — the timing-grade companion of paper Fig 7
//! (`report fig7` prints the full series; this bench repeats each
//! measurement and reports min/mean).
//!
//! Run: `cargo bench --bench factor_time`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{bench_time, dense_baseline, instance, time_cholesky};
use h2opus_tlr::factor::{cholesky, FactorOpts};

fn main() {
    println!("== bench factor_time (paper Fig 7) ==");
    let reps = 3;
    for (name, problem) in [("cov2d", Problem::Cov2d), ("cov3d", Problem::Cov3d)] {
        println!("{name}:");
        println!(
            "  {:>6} {:>6} {:>9} {:>12} {:>12} {:>12}",
            "N", "m", "eps", "min (s)", "mean (s)", "dense (s)"
        );
        for &n in &[1024usize, 2048, 4096] {
            let m = (n / 8).clamp(64, 256);
            for eps in [1e-2, 1e-6] {
                let inst = instance(problem, n, m, eps, 42);
                let opts = FactorOpts {
                    eps,
                    bs: 16,
                    shift: if eps >= 1e-3 { eps * 0.1 } else { 0.0 },
                    schur_comp: eps >= 1e-3,
                    ..Default::default()
                };
                let (min, mean) = bench_time(reps, || {
                    let f = cholesky(inst.tlr.clone(), &opts).expect("factor");
                    std::hint::black_box(&f);
                });
                // Dense baseline once per n, at the tight eps only.
                let dense = if (eps - 1e-6).abs() < 1e-18 && n <= 2048 {
                    format!("{:>12.3}", dense_baseline(inst.gen.as_ref()).0)
                } else {
                    format!("{:>12}", "-")
                };
                println!("  {n:>6} {m:>6} {eps:>9.0e} {min:>12.3} {mean:>12.3} {dense}");
            }
        }
    }
    // One larger instance, single-shot, to expose the asymptotic trend.
    let n = 8192;
    let inst = instance(Problem::Cov3d, n, 256, 1e-6, 42);
    let (_, secs) =
        time_cholesky(inst.tlr, &FactorOpts { eps: 1e-6, bs: 32, ..Default::default() });
    println!("cov3d N={n} m=256 eps=1e-6 (single shot): {secs:.3}s");
}
