//! Bench: non-uniform batched GEMM throughput — the roofline bracket of
//! paper Fig 8b. Sweeps tile size, rank range and batch size for both the
//! sampling shape `(m×k)(k×bs)` and the projection shape `(m×k)ᵀ(m×n)`.
//!
//! Run: `cargo bench --bench gemm_roofline`

use h2opus_tlr::experiments::batched_gemm_roofline;

fn main() {
    println!("== bench gemm_roofline (paper Fig 8b bracket) ==");
    println!(
        "  {:>5} {:>9} {:>5} {:>7} {:>12} {:>12}",
        "m", "k range", "bs", "batch", "AB GF/s", "AtB GF/s"
    );
    for (m, k_lo, k_hi, bs) in [
        (128usize, 8usize, 24usize, 16usize),
        (256, 16, 48, 16),
        (256, 16, 48, 32),
        (512, 16, 48, 32),
        (512, 64, 128, 32),
    ] {
        for batch in [32usize, 128, 512] {
            let (ab, atb) = batched_gemm_roofline(m, k_lo, k_hi, bs, batch, 99);
            println!(
                "  {m:>5} {:>4}-{:<4} {bs:>5} {batch:>7} {ab:>12.2} {atb:>12.2}",
                k_lo, k_hi
            );
        }
    }
    println!("(paper: sampling lands between the AB and AtB MAGMA estimates; batch");
    println!(" size and rank k set the achievable fraction of peak)");
}
