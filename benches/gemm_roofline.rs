//! Bench: non-uniform batched GEMM throughput — the roofline bracket of
//! paper Fig 8b, measured two ways: the old `parallel_map`-over-`matmul`
//! loop (fresh packing panels per call) against the op-stream executor
//! (`batch::NativeBatch`: plan marshaled once, per-worker packing arenas
//! reused across every op). Sweeps tile size, rank range and batch size
//! for both the sampling shape `(m×k)(k×bs)` and the projection shape
//! `(m×k)ᵀ(m×bs)`; ranks are drawn uniformly per tile (skewed batches).
//!
//! Acceptance bar (ISSUE 1): the batched executor must be no slower
//! than the per-call loop on the skewed-rank workload. Record the
//! numbers in EXPERIMENTS.md §Perf.
//!
//! A second table compares the microkernels themselves on a single
//! tile-shaped GEMM: scalar vs the dispatched SIMD kernel vs the
//! mixed-precision (f32-B) path (ISSUE 6 bar: SIMD ≥ 2× scalar at
//! m=n=128; EXPERIMENTS.md §Kernel roofline). Set
//! `H2OPUS_FORCE_SCALAR=1` to verify the fallback leg.
//!
//! Run: `cargo bench --bench gemm_roofline`

use h2opus_tlr::experiments::{kernel_roofline, roofline_loop_vs_batch};

fn main() {
    println!("== bench gemm_roofline (per-kernel roofline; scalar vs SIMD vs mixed) ==");
    let rows = kernel_roofline(128, 128, &[8, 16, 32, 64], 20, 42);
    let kernel = rows.first().map(|r| r.kernel_name).unwrap_or("scalar");
    println!("dispatched kernel: {kernel}");
    println!(
        "  {:>5} {:>5} {:>5} {:>11} {:>11} {:>8} {:>11} {:>8}",
        "m", "n", "k", "scalar", kernel, "speedup", "mixed", "speedup"
    );
    let mut worst_simd = f64::INFINITY;
    for r in &rows {
        let s_active = r.active / r.scalar;
        let s_mixed = r.mixed / r.scalar;
        worst_simd = worst_simd.min(s_active);
        println!(
            "  {:>5} {:>5} {:>5} {:>11.2} {:>11.2} {s_active:>7.2}x {:>11.2} {s_mixed:>7.2}x",
            128, 128, r.k, r.scalar, r.active, r.mixed
        );
    }
    println!("(GFLOP/s, best of 20; speedup vs the scalar microkernel)");
    if kernel == "scalar" {
        println!("(scalar dispatch — SIMD unavailable or H2OPUS_FORCE_SCALAR set; 2x bar not applicable)");
    } else {
        println!("worst-case {kernel}/scalar speedup over k: {worst_simd:.2}x (bar: >= 2x)");
    }

    println!();
    println!("== bench gemm_roofline (paper Fig 8b bracket; loop vs op-stream) ==");
    println!(
        "  {:>5} {:>9} {:>5} {:>7} {:>11} {:>11} {:>8} {:>11} {:>11} {:>8}",
        "m", "k range", "bs", "batch", "AB loop", "AB batch", "speedup", "AtB loop", "AtB batch",
        "speedup"
    );
    let mut worst_ab = f64::INFINITY;
    let mut worst_atb = f64::INFINITY;
    for (m, k_lo, k_hi, bs) in [
        (128usize, 8usize, 24usize, 16usize),
        (256, 16, 48, 16),
        (256, 16, 48, 32),
        (512, 16, 48, 32),
        (512, 64, 128, 32),
    ] {
        for batch in [32usize, 128, 512] {
            let c = roofline_loop_vs_batch(m, k_lo, k_hi, bs, batch, 99);
            let s_ab = c.batch_ab / c.loop_ab;
            let s_atb = c.batch_atb / c.loop_atb;
            worst_ab = worst_ab.min(s_ab);
            worst_atb = worst_atb.min(s_atb);
            println!(
                "  {m:>5} {:>4}-{:<4} {bs:>5} {batch:>7} {:>11.2} {:>11.2} {s_ab:>7.2}x \
                 {:>11.2} {:>11.2} {s_atb:>7.2}x",
                k_lo, k_hi, c.loop_ab, c.batch_ab, c.loop_atb, c.batch_atb
            );
        }
    }
    println!("(GFLOP/s; speedup = batch/loop, higher is better)");
    println!("worst-case batched/loop speedup: AB {worst_ab:.2}x, AtB {worst_atb:.2}x");
    println!("(paper: sampling lands between the AB and AtB MAGMA estimates; batch");
    println!(" size and rank k set the achievable fraction of peak)");
}
