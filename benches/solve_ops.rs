//! Bench: operations on the computed factors — TLR matvec, triangular
//! solve, full direct solve and PCG application (paper §6.2 text: these
//! complete quickly relative to factorization).
//!
//! Run: `cargo bench --bench solve_ops`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{bench_time, instance, time_cholesky};
use h2opus_tlr::factor::FactorOpts;
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::solve::{chol_solve, pcg, tlr_matvec, tlr_trsv_lower, tlr_trsv_lower_t, TlrOp};

fn main() {
    println!("== bench solve_ops (paper §6.2) ==");
    let (n, m) = (4096usize, 256usize);
    let inst = instance(Problem::FracDiff, n, m, 1e-4, 19);
    let (f, fsecs) = time_cholesky(
        inst.tlr.clone(),
        &FactorOpts { eps: 1e-4, bs: 16, shift: 1e-4, ..Default::default() },
    );
    let mut rng = Rng::new(20);
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    println!("fracdiff N={n} m={m} eps=1e-4 (factorization: {fsecs:.3}s):");
    println!("  {:>16} {:>12} {:>12} {:>10}", "op", "min (s)", "mean (s)", "vs factor");

    let reps = 10;
    let (min, mean) = bench_time(reps, || {
        std::hint::black_box(tlr_matvec(&inst.tlr, &x));
    });
    println!("  {:>16} {min:>12.5} {mean:>12.5} {:>9.0}x", "matvec", fsecs / mean);

    let (min, mean) = bench_time(reps, || {
        std::hint::black_box(tlr_trsv_lower(&f.l, &x));
    });
    println!("  {:>16} {min:>12.5} {mean:>12.5} {:>9.0}x", "trsv (L)", fsecs / mean);

    let (min, mean) = bench_time(reps, || {
        std::hint::black_box(tlr_trsv_lower_t(&f.l, &x));
    });
    println!("  {:>16} {min:>12.5} {mean:>12.5} {:>9.0}x", "trsv (L^T)", fsecs / mean);

    let (min, mean) = bench_time(reps, || {
        std::hint::black_box(chol_solve(&f, &x));
    });
    println!("  {:>16} {min:>12.5} {mean:>12.5} {:>9.0}x", "direct solve", fsecs / mean);

    let (min, mean) = bench_time(3, || {
        let r = pcg(&TlrOp(&inst.tlr), &|r| chol_solve(&f, r), &x, 1e-8, 300);
        assert!(r.converged);
        std::hint::black_box(&r);
    });
    println!("  {:>16} {min:>12.5} {mean:>12.5} {:>9.0}x", "pcg (to 1e-8)", fsecs / mean);
}
