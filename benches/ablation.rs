//! Ablation bench: the design choices DESIGN.md calls out, each toggled
//! in isolation on the reference workload —
//!
//! * ARA block size `bs` (paper: 16 for 2D, 32 for 3D),
//! * dynamic batch capacity (paper: workspace-derived),
//! * ARA factor trimming (our QRCP addition; §Perf #8),
//! * Schur compensation (paper §5.1.1),
//! * mixed-precision factor storage (paper §7),
//! * RBT + unpivoted LDLᵀ vs plain LDLᵀ (paper §5.3/§7).
//!
//! Run: `cargo bench --bench ablation`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{bench_time, instance, rank_stats};
use h2opus_tlr::factor::{cholesky, ldlt, rbt_ldlt, FactorOpts};
use h2opus_tlr::tlr::mixed::MixedTlr;

fn main() {
    let (n, m) = (2048usize, 128usize);
    let inst = instance(Problem::Cov3d, n, m, 1e-6, 77);
    println!("== bench ablation (cov3d N={n} m={m} eps=1e-6) ==");

    // ---- ARA block size --------------------------------------------------
    println!("\nARA block size bs (paper: 32 for 3D):");
    println!("  {:>4} {:>11} {:>11} {:>10}", "bs", "min (s)", "mean (s)", "mean rank");
    for bs in [4usize, 8, 16, 32, 64] {
        let opts = FactorOpts { eps: 1e-6, bs, ..Default::default() };
        let mut rank = 0.0;
        let (tmin, tmean) = bench_time(2, || {
            let f = cholesky(inst.tlr.clone(), &opts).expect("factor");
            rank = rank_stats(&f.l).mean;
        });
        println!("  {bs:>4} {tmin:>11.3} {tmean:>11.3} {rank:>10.1}");
    }

    // ---- dynamic batch capacity -----------------------------------------
    println!("\ndynamic batch capacity (scheduling only; factors identical):");
    println!("  {:>9} {:>11} {:>11}", "capacity", "min (s)", "mean (s)");
    for cap in [1usize, 4, 8, 16] {
        let opts = FactorOpts { eps: 1e-6, bs: 16, batch_capacity: cap, ..Default::default() };
        let (tmin, tmean) = bench_time(2, || {
            let f = cholesky(inst.tlr.clone(), &opts).expect("factor");
            std::hint::black_box(&f);
        });
        println!("  {cap:>9} {tmin:>11.3} {tmean:>11.3}");
    }

    // ---- Schur compensation ----------------------------------------------
    println!("\nSchur compensation (robustness cost at loose eps):");
    println!("  {:>14} {:>11} {:>11}", "variant", "min (s)", "mean (s)");
    let loose = instance(Problem::Cov3d, n, m, 1e-2, 77);
    for (name, sc) in [("plain", false), ("schur-comp", true)] {
        let opts = FactorOpts {
            eps: 1e-2,
            bs: 16,
            schur_comp: sc,
            shift: if sc { 0.0 } else { 1e-3 },
            ..Default::default()
        };
        let (tmin, tmean) = bench_time(2, || {
            let f = cholesky(loose.tlr.clone(), &opts).expect("factor");
            std::hint::black_box(&f);
        });
        println!("  {name:>14} {tmin:>11.3} {tmean:>11.3}");
    }

    // ---- mixed-precision factor storage -----------------------------------
    println!("\nmixed-precision factor storage (paper §7):");
    let opts = FactorOpts { eps: 1e-6, bs: 16, ..Default::default() };
    let f = cholesky(inst.tlr.clone(), &opts).expect("factor");
    let full = f.l.memory();
    let mixed = MixedTlr::from_tlr(&f.l);
    let mm = mixed.memory();
    println!(
        "  f64 factor: {:.4} GB | mixed: {:.4} GB ({:.0}% of full)",
        full.total_gb(),
        mm.total_gb(),
        100.0 * mm.total_gb() / full.total_gb()
    );
    let widened = mixed.to_tlr();
    let drift = widened.to_dense_lower().sub(&f.l.to_dense_lower()).norm_max();
    println!("  max |L64 - widen(L32)| = {drift:.2e} (<< eps = 1e-6)");

    // ---- RBT vs plain LDL^T ----------------------------------------------
    println!("\nRBT (depth 2) + unpivoted LDL^T vs plain LDL^T:");
    println!("  {:>12} {:>11} {:>11} {:>10}", "variant", "min (s)", "mean (s)", "mean rank");
    let opts = FactorOpts { eps: 1e-6, bs: 16, ..Default::default() };
    let mut rank = 0.0;
    let (tmin, tmean) = bench_time(2, || {
        let f = ldlt(inst.tlr.clone(), &opts).expect("ldlt");
        rank = rank_stats(&f.l).mean;
    });
    println!("  {:>12} {tmin:>11.3} {tmean:>11.3} {rank:>10.1}", "plain LDL^T");
    let (tmin, tmean) = bench_time(2, || {
        let f = rbt_ldlt(&inst.tlr, 2, &opts).expect("rbt");
        rank = rank_stats(&f.f.l).mean;
    });
    println!("  {:>12} {tmin:>11.3} {tmean:>11.3} {rank:>10.1}", "RBT + LDL^T");
    println!("(RBT pays a transform + rank-mixing cost; it buys pivot-free stability");
    println!(" on indefinite matrices — see factor::rbt tests)");
}
