//! Gaussian-process workload: factor a 3D covariance matrix and use the
//! TLR Cholesky factor to (a) draw correlated samples from N(0, Σ) and
//! (b) evaluate the Gaussian log-likelihood — the two operations the
//! paper's spatial-statistics motivation (§1, refs [41], [16]) needs.
//!
//! Run: `cargo run --release --example covariance_3d`

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::random_ball;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, FactorOpts};
use h2opus_tlr::linalg::norms::dot;
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::solve::{chol_solve, tlr_matvec, tlr_matvec_lower};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};
use h2opus_tlr::tlr::tile::Tile;

fn main() {
    // Observation sites: 4096 random points in a 3D ball (the paper's
    // Fig 1/6b geometry), exponential kernel with ℓ = 0.2.
    let n = 4096;
    let tile = 256;
    let eps = 1e-6;
    let points = random_ball(n, 3, 7);
    let c = kdtree_order(&points, tile);
    let cov = ExpCovariance::paper_default(points.permuted(&c.perm));
    let tlr = build_tlr(
        &cov,
        &c.offsets,
        &BuildOpts { eps, method: Compression::Ara { bs: 32 }, seed: 1 },
    );
    println!("covariance: N={n}, 3D ball, {:.1}x compression", tlr.memory().compression());

    let f =
        cholesky(tlr.clone(), &FactorOpts { eps, bs: 32, ..Default::default() }).expect("SPD");
    println!("TLR Cholesky: {:.3}s", f.stats.seconds);

    // (a) Sampling from N(0, Σ): x = L z with z ~ N(0, I). Verify via the
    //     quadratic form: E[(Lz)ᵀ A^{-1} (Lz)] / N = 1.
    let mut rng = Rng::new(2);
    let trials = 8;
    let mut quad_mean = 0.0;
    for _ in 0..trials {
        let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let x = tlr_matvec_lower(&f.l, &z); // x = L z ~ N(0, LL^T)
        let ainv_x = chol_solve(&f, &x);
        quad_mean += dot(&x, &ainv_x) / n as f64;
    }
    quad_mean /= trials as f64;
    println!("sampling: E[x^T A^-1 x]/N = {quad_mean:.4} (expect ~1.0)");

    // (b) Gaussian log-likelihood of an observed field y:
    //     log p(y) = -1/2 (y^T A^{-1} y + log det A + N log 2π),
    //     log det A = 2 Σ log diag(L) — read off the TLR factor.
    let z: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let y = tlr_matvec_lower(&f.l, &z); // a draw from the model itself
    let ainv_y = chol_solve(&f, &y);
    let quad = dot(&y, &ainv_y);
    let mut logdet = 0.0;
    for k in 0..f.l.nb() {
        if let Tile::Dense(d) = f.l.tile(k, k) {
            for i in 0..d.rows() {
                logdet += 2.0 * d[(i, i)].ln();
            }
        }
    }
    let ll = -0.5 * (quad + logdet + n as f64 * (2.0 * std::f64::consts::PI).ln());
    println!("log-likelihood of a model draw: {ll:.1} (quad {quad:.1}, logdet {logdet:.1})");
    // For a draw from the model, quad/N ~ 1.
    assert!((quad / n as f64 - 1.0).abs() < 0.2, "quadratic form sanity");

    // Round-trip sanity: A (A^{-1} y) = y.
    let ay = tlr_matvec(&tlr, &ainv_y);
    let max_err = ay.iter().zip(&y).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    println!("consistency: max |A A^-1 y - y| = {max_err:.2e}");
}
