//! Pivoting study (paper §5.2 / §6.3): inter-tile symmetric pivoting on
//! covariance and fractional-diffusion problems — selection-cost
//! comparison (Frobenius vs power-iteration 2-norm), rank effects, and
//! the correctness of the permuted factorization P A Pᵀ = L Lᵀ.
//!
//! Run: `cargo run --release --example pivoting_study`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{instance, rank_stats, time_cholesky};
use h2opus_tlr::factor::{FactorOpts, Pivoting};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::profile::{self, Phase};
use h2opus_tlr::solve::{chol_solve, tlr_matvec};

fn main() {
    let (n, m) = (4096, 256);
    for (name, problem, shift) in [
        ("3D covariance", Problem::Cov3d, 0.0),
        ("3D fractional diffusion", Problem::FracDiff, 1e-6),
    ] {
        println!("== {name} (N={n}, m={m}, eps=1e-6) ==");
        let inst = instance(problem, n, m, 1e-6, 11);
        println!(
            "{:>24} {:>11} {:>11} {:>10} {:>9}",
            "variant", "total (s)", "pivot (s)", "mean rank", "max rank"
        );
        for (vname, pivot) in [
            ("unpivoted", Pivoting::None),
            ("Frobenius pivot", Pivoting::Frobenius),
            ("2-norm (power) pivot", Pivoting::Norm2),
            ("random pivot", Pivoting::Random),
        ] {
            let before = profile::snapshot();
            let (f, secs) = time_cholesky(
                inst.tlr.clone(),
                &FactorOpts { eps: 1e-6, bs: 16, shift, pivot, ..Default::default() },
            );
            let prof = profile::snapshot().since(&before);
            let pivot_s = prof.nanos[Phase::Pivot as usize] as f64 / 1e9;
            let rs = rank_stats(&f.l);
            println!(
                "{vname:>24} {secs:>11.3} {pivot_s:>11.3} {:>10.1} {:>9}",
                rs.mean, rs.max
            );

            // Correctness under permutation: solve P A Pᵀ y = P b.
            let mut rng = Rng::new(3);
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = tlr_matvec(&inst.tlr, &x_true);
            let perm = f.scalar_perm();
            let pb: Vec<f64> = perm.iter().map(|&p| b[p]).collect();
            let py = chol_solve(&f, &pb);
            // Un-permute and compare.
            let mut x = vec![0.0; n];
            for (pos, &orig) in perm.iter().enumerate() {
                x[orig] = py[pos];
            }
            let err = x.iter().zip(&x_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(err < 1e-2, "{vname}: permuted solve error {err}");
        }
        println!();
    }
    println!("(paper §6.3: Frobenius selection ~10x cheaper than 2-norm at equal rank");
    println!(" effect; norm-guided pivots can lower covariance ranks, random raises them)");
}
