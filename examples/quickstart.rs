//! Quickstart: build a TLR covariance matrix, factor it, solve a system.
//!
//! Run: `cargo run --release --example quickstart`

use h2opus_tlr::apps::covariance::ExpCovariance;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, FactorOpts};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::solve::{chol_solve, factorization_error, tlr_matvec};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};

fn main() {
    // 1. A spatial-statistics problem: 4096 points on a 2D grid with an
    //    exponential covariance kernel (paper §6 defaults).
    let n = 4096;
    let tile = 256;
    let points = grid(n, 2);

    // 2. KD-tree ordering groups nearby points into tiles (paper §6).
    let clustering = kdtree_order(&points, tile);
    let cov = ExpCovariance::paper_default(points.permuted(&clustering.perm));

    // 3. Compress to TLR form: dense diagonal tiles, adaptive-rank UVᵀ
    //    off-diagonal tiles, each compressed ab initio by randomized
    //    sampling — the full N x N matrix is never materialized.
    let eps = 1e-6;
    let tlr = build_tlr(
        &cov,
        &clustering.offsets,
        &BuildOpts { eps, method: Compression::Ara { bs: 16 }, seed: 1 },
    );
    let mem = tlr.memory();
    println!(
        "TLR matrix: N={n}, {} tiles of {tile}, {:.4} GB vs {:.4} GB dense ({:.1}x)",
        tlr.nb(),
        mem.total_gb(),
        mem.full_dense_gb(),
        mem.compression()
    );

    // 4. Left-looking TLR Cholesky with batched adaptive randomized
    //    approximation (the paper's core algorithm).
    let f = cholesky(tlr.clone(), &FactorOpts { eps, bs: 16, ..Default::default() })
        .expect("covariance matrices are SPD");
    println!(
        "factored in {:.3}s — {:.1}% of the work in GEMM-shaped kernels",
        f.stats.seconds,
        100.0 * f.stats.profile.gemm_share()
    );

    // 5. Verify ‖A − L Lᵀ‖₂ by power iteration (paper §6) and solve.
    let err = factorization_error(&tlr, &f, 20, 2);
    println!("||A - LL^T||_2 ~ {err:.2e} (target eps = {eps:.0e})");

    let mut rng = Rng::new(3);
    let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let b = tlr_matvec(&tlr, &x_true);
    let x = chol_solve(&f, &b);
    let max_err = x.iter().zip(&x_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    println!("solved A x = b: max |x - x_true| = {max_err:.2e}");
}
