//! Fractional-diffusion preconditioning (paper §6.2): build the TLR
//! Cholesky of `A + εI` at a sweep of loose thresholds and use each as a
//! PCG preconditioner for the ill-conditioned system `A x = b`,
//! reproducing the accuracy/iterations trade-off of Fig 9/10.
//!
//! Run: `cargo run --release --example fracdiff_pcg`

use h2opus_tlr::apps::fracdiff::FracDiffusion;
use h2opus_tlr::apps::geometry::grid;
use h2opus_tlr::apps::kdtree::kdtree_order;
use h2opus_tlr::factor::{cholesky, FactorOpts};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::solve::{chol_solve, pcg, TlrOp};
use h2opus_tlr::tlr::construct::{build_tlr, BuildOpts, Compression};

fn main() {
    let n = 4096;
    let tile = 256;
    let points = grid(n, 3);
    let c = kdtree_order(&points, tile);
    // High-contrast coefficients put kappa in the paper's ~1e7 regime.
    let fd = FracDiffusion::with_contrast(points.permuted(&c.perm), 0.5, 1e-4, 6.0);
    println!("3D fractional diffusion: N={n}, s=0.5, kappa ~ {:.1e}", fd.cond_estimate());

    // The "exact" operator at a tight threshold (what we want to solve).
    let a = build_tlr(
        &fd,
        &c.offsets,
        &BuildOpts { eps: 1e-8, method: Compression::Ara { bs: 32 }, seed: 1 },
    );
    let mut rng = Rng::new(2);
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    // Unpreconditioned CG flounders on this conditioning.
    let plain = pcg(&TlrOp(&a), &|r| r.to_vec(), &b, 1e-6, 300);
    println!(
        "CG (no preconditioner): {} iters, converged={}, residual {:.1e}",
        plain.iters,
        plain.converged,
        plain.history.last().unwrap()
    );

    println!(
        "{:>9} {:>11} {:>11} {:>7} {:>10}",
        "eps", "build (s)", "factor (s)", "iters", "converged"
    );
    for eps in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5] {
        // Preconditioner: factor A + εI compressed at ε (paper recipe).
        let t0 = std::time::Instant::now();
        let pre = build_tlr(
            &fd,
            &c.offsets,
            &BuildOpts { eps, method: Compression::Ara { bs: 32 }, seed: 1 },
        );
        let build_s = t0.elapsed().as_secs_f64();
        match cholesky(pre, &FactorOpts { eps, bs: 32, shift: eps, ..Default::default() }) {
            Ok(f) => {
                let r = pcg(&TlrOp(&a), &|r| chol_solve(&f, r), &b, 1e-6, 300);
                println!(
                    "{eps:>9.0e} {build_s:>11.3} {:>11.3} {:>7} {:>10}",
                    f.stats.seconds, r.iters, r.converged
                );
            }
            Err(e) => println!("{eps:>9.0e} {build_s:>11.3}  factorization failed: {e}"),
        }
    }
    println!("(paper Fig 9: looser thresholds need more iterations; the loosest stalls)");
}
