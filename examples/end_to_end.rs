//! End-to-end driver: exercises the full three-layer system on a real
//! small workload and proves every layer composes.
//!
//!   L1  Pallas sampling kernels (python/compile/kernels/sample.py)
//!   L2  JAX graphs lowered AOT to HLO text (python/compile/aot.py)
//!   L3  this rust coordinator, which loads the artifacts via PJRT and
//!       runs the TLR Cholesky's ARA hot loop through them
//!
//! The driver factors a spatial-statistics covariance matrix with BOTH
//! backends (native gemm and PJRT artifacts), checks they agree, runs the
//! paper's headline comparisons (dense baseline speedup, memory
//! compression, GEMM-dominated profile), and finishes with the §6.2
//! preconditioned-CG workload. Results land in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`

use h2opus_tlr::config::Problem;
use h2opus_tlr::experiments::{dense_baseline, instance};
use h2opus_tlr::factor::{cholesky_with, FactorOpts};
use h2opus_tlr::linalg::rng::Rng;
use h2opus_tlr::runtime::{default_artifacts_dir, Backend, PjrtEngine};
use h2opus_tlr::solve::{chol_solve, factorization_error, pcg, tlr_matvec, TlrOp};

fn main() {
    println!("=== H2OPUS-TLR end-to-end driver ===\n");

    // ---- Stage 0: the AOT artifacts (L1+L2 build products). ----------
    let dir = default_artifacts_dir();
    let engine = match PjrtEngine::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("no artifacts at {dir:?}: {e}\nrun `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "[L1/L2] {} AOT artifacts loaded from {dir:?}",
        engine.manifest().variants.len()
    );

    // ---- Stage 1: problem + TLR compression (the L3 substrate). ------
    let (n, m, eps) = (1024usize, 64usize, 1e-6);
    let inst = instance(Problem::Cov2d, n, m, eps, 1);
    let mem = inst.tlr.memory();
    println!(
        "[L3]    cov2d N={n} m={m}: {:.1}x compression ({:.2} MB vs {:.2} MB dense)",
        mem.compression(),
        mem.total_gb() * 1024.0,
        mem.full_dense_gb() * 1024.0
    );

    // ---- Stage 2: factor through BOTH backends; they must agree. -----
    let opts = FactorOpts { eps, bs: 8, ..Default::default() };
    let t0 = std::time::Instant::now();
    let f_native = cholesky_with(inst.tlr.clone(), &opts, Backend::Native).expect("native");
    let native_s = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let f_pjrt = cholesky_with(inst.tlr.clone(), &opts, Backend::Pjrt(&engine)).expect("pjrt");
    let pjrt_s = t0.elapsed().as_secs_f64();
    let ln = f_native.l.to_dense_lower();
    let lp = f_pjrt.l.to_dense_lower();
    let diff = ln.sub(&lp).norm_fro() / ln.norm_fro();
    let st = engine.stats();
    println!(
        "[L3]    native backend: {native_s:.3}s | PJRT backend: {pjrt_s:.3}s \
         ({} launches, {} executables)",
        st.launches, st.compiled
    );
    println!("[check] backend agreement: |L_native - L_pjrt| / |L| = {diff:.2e}");
    assert!(diff < 1e-6, "backends diverged");

    // ---- Stage 3: the paper's headline comparisons. -------------------
    let err = factorization_error(&inst.tlr, &f_native, 20, 2);
    println!("[check] ||A - LL^T||_2 ~ {err:.2e} (eps = {eps:.0e})");
    let (dense_s, dense_gf) = dense_baseline(inst.gen.as_ref());
    println!(
        "[perf]  dense Cholesky baseline: {dense_s:.3}s ({dense_gf:.1} GFLOP/s) — \
         dense/TLR time ratio {:.1}x (crossover grows with N; see `report fig7`)",
        dense_s / native_s
    );
    println!(
        "[perf]  GEMM-shaped share of TLR work: {:.1}% (paper: 80-90%)",
        100.0 * f_native.stats.profile.gemm_share()
    );

    // ---- Stage 4: a real workload on the factor. ----------------------
    // Batch of correlated-field solves (the GP use case): A x = b_i.
    let mut rng = Rng::new(3);
    let batch = 16;
    let t0 = std::time::Instant::now();
    let mut worst = 0.0f64;
    for _ in 0..batch {
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = tlr_matvec(&inst.tlr, &x_true);
        let x = chol_solve(&f_native, &b);
        let e = x.iter().zip(&x_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        worst = worst.max(e);
    }
    let solve_s = t0.elapsed().as_secs_f64();
    println!(
        "[run]   {batch} direct solves: {:.1} ms each, worst error {worst:.2e}",
        1e3 * solve_s / batch as f64
    );

    // Ill-conditioned fracdiff PCG (paper §6.2) at the same small scale.
    let fd = instance(Problem::FracDiff, n, m, 1e-3, 4);
    let pre = cholesky_with(
        fd.tlr.clone(),
        &FactorOpts { eps: 1e-3, bs: 8, shift: 1e-3, ..Default::default() },
        Backend::Pjrt(&engine),
    )
    .expect("preconditioner");
    let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let r = pcg(&TlrOp(&fd.tlr), &|r| chol_solve(&pre, r), &b, 1e-8, 300);
    println!(
        "[run]   fracdiff PCG with PJRT-built preconditioner: {} iters, converged={}",
        r.iters, r.converged
    );
    assert!(r.converged);

    println!("\nend_to_end: ALL STAGES OK");
}
