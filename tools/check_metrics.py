#!/usr/bin/env python3
"""Schema validator for the obs JSON snapshot (`serve --metrics-dump`,
`report --metrics-dump`, `obs::json_snapshot()`).

Validates, against schema version 1 (the metric-name contract in
rust/src/serve/mod.rs):

 * top-level shape: `version == 1`, `schema == "h2opus-obs"`, and the
   required sections `phases`, `kernels`, `batch`, `serve`, `shards`,
   `histograms`, `factor_generations`, `update_errors`, `resilience`;
 * lifecycle sections: `update_errors` carries every update-error
   class as a non-negative counter; `factor_generations` maps
   16-hex-digit keys to non-negative generation gauges;
 * `resilience` carries exactly the resilience classes (retries,
   deadline expiries, panics, degraded admits, quarantines, injected
   faults) as non-negative counters;
 * every histogram in `histograms`: required fields, bucket lower
   bounds strictly increasing, bucket counts summing to `count`,
   percentiles null exactly when empty and ordered p50 <= p95 <= p99
   when present;
 * counters are non-negative numbers; nullable ratios
   (`batching_efficiency`, `mean_wave_width`, `imbalance`) are numbers
   or null, never NaN strings.

Exit status 0 = valid, 1 = findings, 2 = unreadable input.

    python3 tools/check_metrics.py target/ci-metrics.json
"""

import json
import sys

EXPECTED_HISTS = [
    "request_wait_ns",
    "panel_exec_ns",
    "factor_load_owned_ns",
    "factor_load_mapped_ns",
    "pcg_iters",
    "wave_exec_ns",
]

SHARD_ERROR_CLASSES = [
    "parse", "unknown_worker", "duplicate_worker", "last_worker", "store",
]

UPDATE_ERROR_CLASSES = ["bad_shape", "indefinite_diagonal"]

RESILIENCE_CLASSES = [
    "retry_attempt", "retry_exhausted", "deadline_expired", "worker_panic",
    "degraded", "quarantined", "fault_injected",
]

findings = []


def fail(msg):
    findings.append(msg)


def is_count(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and v >= 0


def check_ratio(obj, section, key):
    v = obj.get(key, "missing")
    if v is None:
        return
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        fail(f"{section}.{key}: expected number or null, got {v!r}")


def check_hist(name, h):
    where = f"histograms.{name}"
    if not isinstance(h, dict):
        fail(f"{where}: not an object")
        return
    for key in ("count", "sum", "mean", "p50", "p95", "p99", "buckets"):
        if key not in h:
            fail(f"{where}: missing field {key}")
            return
    if not is_count(h["count"]) or not is_count(h["sum"]):
        fail(f"{where}: count/sum must be non-negative numbers")
        return
    buckets = h["buckets"]
    if not isinstance(buckets, list):
        fail(f"{where}: buckets is not a list")
        return
    total = 0
    prev_lower = -1
    for i, b in enumerate(buckets):
        if (not isinstance(b, list) or len(b) != 2
                or not is_count(b[0]) or not is_count(b[1]) or b[1] == 0):
            fail(f"{where}: bucket {i} is not a [lower, count>0] pair")
            return
        if b[0] <= prev_lower:
            fail(f"{where}: bucket lower bounds not strictly increasing "
                 f"at index {i}")
            return
        prev_lower = b[0]
        total += b[1]
    if total != h["count"]:
        fail(f"{where}: bucket counts sum to {total} but count is "
             f"{h['count']}")
    empty = h["count"] == 0
    pcts = [h["p50"], h["p95"], h["p99"]]
    if empty:
        for tag, p in zip(("p50", "p95", "p99"), pcts):
            if p is not None:
                fail(f"{where}: empty histogram must have null {tag}")
        if h["mean"] is not None:
            fail(f"{where}: empty histogram must have null mean")
    else:
        for tag, p in zip(("p50", "p95", "p99"), pcts):
            if not is_count(p):
                fail(f"{where}: {tag} must be a number when count > 0")
                return
        if not (pcts[0] <= pcts[1] <= pcts[2]):
            fail(f"{where}: percentiles not ordered: {pcts}")


def check(doc):
    if not isinstance(doc, dict):
        fail("top level is not an object")
        return
    if doc.get("version") != 1:
        fail(f"version: expected 1, got {doc.get('version')!r}")
    if doc.get("schema") != "h2opus-obs":
        fail(f"schema: expected 'h2opus-obs', got {doc.get('schema')!r}")
    for section in ("phases", "kernels", "batch", "serve", "shards",
                    "histograms", "factor_generations", "update_errors",
                    "resilience"):
        if not isinstance(doc.get(section), dict):
            fail(f"missing or non-object section: {section}")
    if findings:
        return

    for name, p in doc["phases"].items():
        if not (isinstance(p, dict) and is_count(p.get("nanos"))
                and is_count(p.get("flops"))):
            fail(f"phases.{name}: expected {{nanos, flops}} counters")

    kern = doc["kernels"]
    if not isinstance(kern.get("calls"), dict):
        fail("kernels.calls: missing")
    else:
        for name, k in kern["calls"].items():
            if not (isinstance(k, dict) and is_count(k.get("f64_calls"))
                    and is_count(k.get("mixed_calls"))):
                fail(f"kernels.calls.{name}: expected f64/mixed call counts")
    if not is_count(kern.get("f32_bytes_saved")):
        fail("kernels.f32_bytes_saved: expected a non-negative number")

    batch = doc["batch"]
    for key in ("waves", "ops", "flops"):
        if not is_count(batch.get(key)):
            fail(f"batch.{key}: expected a non-negative number")
    check_ratio(batch, "batch", "mean_wave_width")

    serve = doc["serve"]
    for key in ("requests", "batches", "nanos", "rejected"):
        if not is_count(serve.get(key)):
            fail(f"serve.{key}: expected a non-negative number")
    check_ratio(serve, "serve", "batching_efficiency")

    shards = doc["shards"]
    routed = shards.get("routed")
    if not (isinstance(routed, list) and all(is_count(c) for c in routed)):
        fail("shards.routed: expected a list of counters")
    for key in ("rebalances", "moved_shards"):
        if not is_count(shards.get(key)):
            fail(f"shards.{key}: expected a non-negative number")
    check_ratio(shards, "shards", "imbalance")
    errors = shards.get("errors")
    if not isinstance(errors, dict):
        fail("shards.errors: missing")
    else:
        for cls in SHARD_ERROR_CLASSES:
            if not is_count(errors.get(cls)):
                fail(f"shards.errors.{cls}: expected a non-negative number")

    uerrs = doc["update_errors"]
    for cls in UPDATE_ERROR_CLASSES:
        if not is_count(uerrs.get(cls)):
            fail(f"update_errors.{cls}: expected a non-negative number")
    for cls in uerrs:
        if cls not in UPDATE_ERROR_CLASSES:
            fail(f"update_errors.{cls}: unknown class")

    res = doc["resilience"]
    for cls in RESILIENCE_CLASSES:
        if not is_count(res.get(cls)):
            fail(f"resilience.{cls}: expected a non-negative number")
    for cls in res:
        if cls not in RESILIENCE_CLASSES:
            fail(f"resilience.{cls}: unknown class")

    gens = doc["factor_generations"]
    for key, gen in gens.items():
        if not (isinstance(key, str) and len(key) == 16
                and all(c in "0123456789abcdef" for c in key)):
            fail(f"factor_generations: key {key!r} is not 16 hex digits")
        elif not is_count(gen):
            fail(f"factor_generations.{key}: expected a non-negative "
                 f"generation")

    hists = doc["histograms"]
    for name in EXPECTED_HISTS:
        if name not in hists:
            fail(f"histograms: missing {name}")
    for name, h in hists.items():
        check_hist(name, h)


def main(argv):
    if len(argv) != 2:
        print(__doc__.strip().splitlines()[0])
        print(f"usage: {argv[0]} SNAPSHOT.json")
        return 2
    try:
        with open(argv[1], encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"{argv[1]}: cannot read/parse: {e}")
        return 2
    check(doc)
    if findings:
        print(f"{argv[1]}: {len(findings)} finding(s):")
        for f in findings:
            print("  " + f)
        return 1
    n_hists = len(doc.get("histograms", {}))
    print(f"{argv[1]}: valid h2opus-obs snapshot v1 ({n_hists} histograms)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
