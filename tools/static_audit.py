#!/usr/bin/env python3
"""Static audit for the h2opus-tlr Rust tree (no toolchain in the authoring
container, so this stands in for `cargo build` until CI runs).

Checks, in increasing order of cleverness:

 1. delimiter balance — (), [], {} — over comment/string-stripped source;
 2. cargo-fmt line-length violations (>100 columns);
 3. lifetime token syntax (`'` must start a char literal, a lifetime
    identifier, or `'static`);
 4. generic-parameter-list balance for `impl<...>` / `fn name<...>` /
    `struct|enum|trait Name<...>` headers;
 5. trait-impl cross-check: every `impl Trait for Type` body may only
    define methods the trait declares, with matching arity, and must
    define every trait method that has no default body (traits defined
    in this crate only);
 6. import cross-check: every leaf of a `use h2opus_tlr::...` tree in
    tests/benches/examples must be defined (or re-exported) in the
    named module;
 7. known clippy classes: `.len() == 0` / `!= 0` / `> 0`, comparisons
    with bool literals;
 8. SIMD hygiene: every `#[target_feature]` fn is `unsafe`, sits inside
    a `#[cfg(target_arch = ...)]`-gated module (or carries the cfg
    itself), AVX-512 variants carry `#[cfg(feature = "avx512")]`, and
    the fn is only referenced from the file that defines it — all
    callers must go through the runtime dispatch table in simd.rs;
 9. error observability: every variant of `serve/`'s error enums
    (`ServeError`, `ShardError`) is matched inside its dedicated
    obs-mapping fn (`reject_reason`, `shard_error_class`), so no error
    path can be added without a counter or flight-recorder event;
10. unsafe hygiene: every `unsafe fn` / `unsafe {}` block / `unsafe
    impl` carries a `// SAFETY:` comment (an `unsafe fn` may use a
    `/// # Safety` doc section instead) within 40 lines, bounded by the
    enclosing fn header for blocks; every `#[kani::proof]` harness sits
    inside a `#[cfg(kani)]`-gated module (tier-1 rustc never compiles
    it); fns returning raw pointers are `pub(crate)` or narrower; and
    the full site inventory matches the committed
    `tools/unsafe_inventory.json` — regenerate with
    `python3 tools/static_audit.py --write-inventory` so every new
    unsafe site shows up as a reviewable diff.

Exit status 0 = clean, 1 = findings. Run from the repo root:

    python3 tools/static_audit.py
"""

import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MAX_WIDTH = 100

findings = []


def warn(path, line, msg):
    findings.append(f"{os.path.relpath(path, ROOT)}:{line}: {msg}")


# --------------------------------------------------------------- lexer


def strip_code(text, path):
    """Replace comments, strings and char literals with spaces (newlines
    kept) so structural checks see only code. Handles nested block
    comments, raw strings r#"..."#, byte strings, escapes, and the
    char-literal vs lifetime ambiguity."""
    out = []
    i, n = 0, len(text)
    line = 1

    def put(c):
        out.append(c)

    def blank(c):
        out.append("\n" if c == "\n" else " ")

    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
        # Line comment.
        if c == "/" and i + 1 < n and text[i + 1] == "/":
            while i < n and text[i] != "\n":
                blank(text[i])
                i += 1
            continue
        # Block comment (nested).
        if c == "/" and i + 1 < n and text[i + 1] == "*":
            depth = 0
            while i < n:
                if text[i] == "/" and i + 1 < n and text[i + 1] == "*":
                    depth += 1
                    blank(text[i])
                    blank(text[i + 1])
                    i += 2
                    continue
                if text[i] == "*" and i + 1 < n and text[i + 1] == "/":
                    depth -= 1
                    blank(text[i])
                    blank(text[i + 1])
                    i += 2
                    if depth == 0:
                        break
                    continue
                if text[i] == "\n":
                    line += 1
                blank(text[i])
                i += 1
            continue
        # Raw string (and byte-raw): r"..."  r#"..."#  br#"..."#
        m = re.match(r'b?r(#*)"', text[i:])
        if m and (i == 0 or not (text[i - 1].isalnum() or text[i - 1] == "_")):
            hashes = m.group(1)
            close = '"' + hashes
            j = i + len(m.group(0))
            end = text.find(close, j)
            if end == -1:
                warn(path, line, "unterminated raw string")
                end = n - len(close)
            for k in range(i, end + len(close)):
                if text[k] == "\n":
                    line += 1
                blank(text[k])
            i = end + len(close)
            continue
        # Plain / byte string.
        if c == '"' or (c == "b" and i + 1 < n and text[i + 1] == '"'):
            if c == "b":
                blank(c)
                i += 1
            blank(text[i])
            i += 1
            while i < n:
                if text[i] == "\\":
                    blank(text[i])
                    if i + 1 < n:
                        if text[i + 1] == "\n":
                            line += 1
                        blank(text[i + 1])
                    i += 2
                    continue
                if text[i] == '"':
                    blank(text[i])
                    i += 1
                    break
                if text[i] == "\n":
                    line += 1
                blank(text[i])
                i += 1
            continue
        # ' — char literal, byte char, or lifetime.
        if c == "'" or (c == "b" and i + 1 < n and text[i + 1] == "'"):
            if c == "b":
                blank(c)
                i += 1
            start = i
            # 'x' or '\x..' → char literal; otherwise a lifetime.
            if i + 1 < n and text[i + 1] == "\\":
                j = i + 2
                while j < n and text[j] != "'":
                    j += 1
                for k in range(i, min(j + 1, n)):
                    blank(text[k])
                i = j + 1
                continue
            if i + 2 < n and text[i + 2] == "'" and text[i + 1] != "'":
                blank(text[i])
                blank(text[i + 1])
                blank(text[i + 2])
                i += 3
                continue
            # Lifetime: keep it (check 3 runs on stripped text).
            put(text[i])
            i += 1
            if i >= n or not (text[i].isalpha() or text[i] == "_"):
                warn(path, line, "stray `'` (not a char literal or lifetime)")
                continue
            while i < n and (text[i].isalnum() or text[i] == "_"):
                put(text[i])
                i += 1
            _ = start
            continue
        put(c)
        i += 1
    return "".join(out)


# ------------------------------------------------------------ checks 1-4


def check_balance(path, stripped):
    pairs = {")": "(", "]": "[", "}": "{"}
    stack = []
    line = 1
    for ch in stripped:
        if ch == "\n":
            line += 1
        elif ch in "([{":
            stack.append((ch, line))
        elif ch in ")]}":
            if not stack or stack[-1][0] != pairs[ch]:
                warn(path, line, f"unbalanced `{ch}`")
                return
            stack.pop()
    if stack:
        warn(path, stack[-1][1], f"unclosed `{stack[-1][0]}`")


def check_line_lengths(path, text, stripped):
    """Overlong lines, except where everything past the limit is string
    content — rustfmt never splits string literals, so those lines do
    not fail `cargo fmt --check`."""
    slines = stripped.split("\n")
    for ln, line in enumerate(text.split("\n"), 1):
        if len(line) <= MAX_WIDTH:
            continue
        tail = slines[ln - 1][MAX_WIDTH:] if ln - 1 < len(slines) else ""
        if not tail.strip(" );,#\""):
            continue
        warn(path, ln, f"line is {len(line)} cols (fmt max {MAX_WIDTH})")


def check_generics(path, stripped):
    """Angle-bracket balance of generic parameter lists that directly
    follow `impl` / `fn name` / `struct|enum|trait Name`."""
    for m in re.finditer(
        r"\b(impl|fn\s+\w+|struct\s+\w+|enum\s+\w+|trait\s+\w+)\s*<", stripped
    ):
        j = m.end() - 1
        depth = 0
        ok = False
        while j < len(stripped) and j < m.end() + 4000:
            c = stripped[j]
            if c == "<":
                depth += 1
            elif c == ">":
                if stripped[j - 1] == "-":  # `->` inside e.g. Fn(...) -> T
                    j += 1
                    continue
                depth -= 1
                if depth == 0:
                    ok = True
                    break
            elif c in ";{" and depth == 0:
                break
            j += 1
        if not ok:
            line = stripped.count("\n", 0, m.start()) + 1
            warn(path, line, f"unbalanced generic list after `{m.group(1)}`")


# ------------------------------------------------- trait-impl signatures


def top_level_params(params):
    """Count parameters in a comma-separated list, ignoring commas nested
    in <>, (), []."""
    depth = 0
    count = 0
    cur = ""
    for c in params:
        if c in "<([":
            depth += 1
        elif c in ">)]":
            depth -= 1
        elif c == "," and depth == 0:
            if cur.strip():
                count += 1
            cur = ""
            continue
        cur += c
    if cur.strip():
        count += 1
    return count


def body_span(text, open_idx):
    """Span of a {...} block starting at text[open_idx] == '{'."""
    depth = 0
    for j in range(open_idx, len(text)):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                return text[open_idx + 1 : j], j
    return text[open_idx + 1 :], len(text)


FN_RE = re.compile(r"\bfn\s+(\w+)\s*(?:<[^>]*>)?\s*\(")


def fn_sigs(body):
    """name -> (arity, has_default_body) for fns declared at any depth of
    `body` (nested fns are rare in this tree; good enough)."""
    sigs = {}
    for m in FN_RE.finditer(body):
        # Find matching close paren.
        depth = 0
        j = m.end() - 1
        while j < len(body):
            if body[j] == "(":
                depth += 1
            elif body[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        params = body[m.end() : j]
        arity = top_level_params(params)
        # Default body? look ahead for `{` before `;`.
        k = j
        has_body = False
        while k < len(body):
            if body[k] == "{":
                has_body = True
                break
            if body[k] == ";":
                break
            k += 1
        if m.group(1) not in sigs:
            sigs[m.group(1)] = (arity, has_body)
    return sigs


TRAIT_RE = re.compile(r"\btrait\s+(\w+)[^;{]*\{")
IMPL_RE = re.compile(
    r"\bimpl\s*(?:<[^>]*>)?\s*([A-Za-z_]\w*)\s*(?:<[^>]*>)?\s+for\s+"
)


def collect_traits(files):
    traits = {}
    for path, stripped in files.items():
        for m in TRAIT_RE.finditer(stripped):
            body, _ = body_span(stripped, m.end() - 1)
            traits[m.group(1)] = (path, fn_sigs(body))
    return traits


def check_impls(files, traits):
    # std/core traits whose shapes rustc checks for us.
    external = {
        "Default", "Drop", "Clone", "Display", "Debug", "Error", "From",
        "Iterator", "PartialEq", "Eq", "Hash", "Ord", "PartialOrd", "Deref",
        "DerefMut", "Index", "IndexMut", "Send", "Sync", "Copy", "Fn",
        "FnMut", "FnOnce", "ExactSizeIterator", "IntoIterator", "AsRef",
        "TryFrom", "FromIterator", "Add", "Sub", "Mul", "Neg", "Write",
    }
    for path, stripped in files.items():
        for m in IMPL_RE.finditer(stripped):
            name = m.group(1)
            if name in external or name not in traits:
                continue
            tpath, tsigs = traits[name]
            open_idx = stripped.find("{", m.end())
            if open_idx == -1:
                continue
            body, _ = body_span(stripped, open_idx)
            isigs = fn_sigs(body)
            line = stripped.count("\n", 0, m.start()) + 1
            for fname, (arity, _) in isigs.items():
                if fname not in tsigs:
                    warn(path, line, f"impl {name}: fn `{fname}` not in trait "
                                     f"({os.path.relpath(tpath, ROOT)})")
                elif tsigs[fname][0] != arity:
                    warn(path, line, f"impl {name}: fn `{fname}` arity "
                                     f"{arity} != trait's {tsigs[fname][0]}")
            for fname, (_, has_default) in tsigs.items():
                if not has_default and fname not in isigs:
                    warn(path, line, f"impl {name}: missing trait fn `{fname}`")


# ----------------------------------------------------- import cross-check


def module_of(path):
    rel = os.path.relpath(path, os.path.join(ROOT, "rust", "src"))
    parts = rel[:-3].split(os.sep)  # strip .rs
    if parts[-1] in ("mod", "lib"):
        parts = parts[:-1]
    return "::".join(parts)


DEF_RE = re.compile(
    r"\bpub(?:\s*\(crate\))?\s+(?:unsafe\s+)?"
    r"(?:fn|struct|enum|trait|const|static|type|mod|union)\s+(\w+)"
)
REEXPORT_RE = re.compile(r"\bpub\s+use\s+([^;]+);")


def use_leaves(tree):
    """Flatten one `use` tree into its leaf names."""
    tree = tree.strip()
    m = re.match(r"^(.*?)\{(.*)\}$", tree, re.S)
    leaves = []
    if m:
        prefix = m.group(1)
        depth = 0
        item = ""
        for c in m.group(2) + ",":
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
            if c == "," and depth == 0:
                if item.strip():
                    leaves.extend(use_leaves(prefix + item.strip()))
                item = ""
            else:
                item += c
        return leaves
    if " as " in tree:
        tree = tree.split(" as ")[0].strip()
    leaves.append(tree)
    return leaves


def collect_pub_symbols(src_files):
    """module path -> set of pub names (incl. re-exported leaf names)."""
    syms = {}
    for path, stripped in src_files.items():
        mod = module_of(path)
        names = syms.setdefault(mod, set())
        for m in DEF_RE.finditer(stripped):
            names.add(m.group(1))
        for m in REEXPORT_RE.finditer(stripped):
            for leaf in use_leaves(m.group(1)):
                name = leaf.rstrip(":").split("::")[-1].strip()
                if name and name != "*":
                    names.add(name)
        # Local macros that generate `pub fn $name`: credit the first
        # ident argument of each invocation (e.g. `mapped_loader!`).
        for mm in re.finditer(r"macro_rules!\s*(\w+)", stripped):
            body, _ = body_span(stripped, stripped.find("{", mm.end()))
            if not re.search(r"pub\s+fn\s+\$", body):
                continue
            for call in re.finditer(mm.group(1) + r"!\s*\(\s*(\w+)", stripped):
                names.add(call.group(1))
    # Modules themselves are importable from their parent.
    for mod in list(syms):
        if "::" in mod:
            parent, leaf = mod.rsplit("::", 1)
            syms.setdefault(parent, set()).add(leaf)
        elif mod:
            syms.setdefault("", set()).add(mod)
    return syms


USE_CRATE_RE = re.compile(r"\buse\s+h2opus_tlr::([^;]+);")


def check_imports(all_files, syms):
    star_ok = re.compile(r"\*$")
    for path, stripped in all_files.items():
        for m in USE_CRATE_RE.finditer(stripped):
            for leaf in use_leaves(m.group(1)):
                leaf = re.sub(r"\s+", "", leaf)
                if star_ok.search(leaf):
                    continue
                parts = leaf.split("::")
                name = parts[-1]
                mod = "::".join(parts[:-1])
                line = stripped.count("\n", 0, m.start()) + 1
                if mod not in syms:
                    # Could be a deep module path used as a name prefix.
                    if "::".join(parts) in syms:
                        continue
                    warn(path, line, f"use h2opus_tlr::{leaf}: no module `{mod}`")
                elif name not in syms[mod] and name != "self":
                    warn(path, line,
                         f"use h2opus_tlr::{leaf}: `{name}` not pub in `{mod}`")


# ----------------------------------------------------------- simd hygiene


TF_ATTR_RE = re.compile(r'#\[target_feature\(enable\s*=\s*"([^"]+)"\)\]')
ARCH_MOD_RE = re.compile(r"#\[cfg\([^\]]*target_arch[^\]]*\)\]\s*(?:pub\s+)?mod\s+\w+\s*\{")


def check_simd_hygiene(all_files):
    """`#[target_feature]` fns must be unsafe, arch-gated, feature-gated
    for AVX-512, and reached only via the dispatch table that guards
    them with a runtime CPU check (i.e. never called from another
    file)."""
    tf_fns = {}  # fn name -> defining path
    for path, stripped in all_files.items():
        if "target_feature" not in stripped:
            continue
        arch_spans = []
        for m in ARCH_MOD_RE.finditer(stripped):
            open_idx = stripped.find("{", m.start())
            _, close = body_span(stripped, open_idx)
            arch_spans.append((m.start(), close))
        for m in TF_ATTR_RE.finditer(stripped):
            line = stripped.count("\n", 0, m.start()) + 1
            fm = re.search(r"\bfn\s+(\w+)", stripped[m.end():])
            if fm is None:
                warn(path, line, "#[target_feature] not followed by a fn")
                continue
            name = fm.group(1)
            head = stripped[m.end() : m.end() + fm.end()]
            tf_fns.setdefault(name, path)
            if not re.search(r"\bunsafe\s+fn\b", head):
                warn(path, line, f"#[target_feature] fn `{name}` must be `unsafe`")
            gated = any(s <= m.start() < e for s, e in arch_spans)
            nearby = stripped[max(0, m.start() - 400) : m.start()]
            if not gated and "target_arch" not in nearby:
                warn(path, line,
                     f"#[target_feature] fn `{name}` not cfg-gated to an arch")
            if "avx512" in m.group(1) and 'feature = "avx512"' not in nearby:
                warn(path, line,
                     f"AVX-512 fn `{name}` missing #[cfg(feature = \"avx512\")]")
    for path, stripped in all_files.items():
        for name, home in tf_fns.items():
            if path == home:
                continue
            cm = re.search(r"\b" + name + r"\s*\(", stripped)
            if cm:
                line = stripped.count("\n", 0, cm.start()) + 1
                warn(path, line,
                     f"`{name}` is #[target_feature]; call it only through the "
                     f"dispatch table in {os.path.relpath(home, ROOT)}")


# ------------------------------------------- serve error observability


# (subdir, enum, mapping fn): the fn must match every variant of the
# enum, so that each error constructed in the subsystem lands in a
# counter or a flight-recorder event (obs::RejectReason /
# obs::ShardErrorClass / obs::UpdateErrorClass). The subdir scopes the
# scan so an unrelated enum of the same name elsewhere never shadows
# the one under audit.
ERROR_MAPPINGS = [
    ("serve", "ServeError", "reject_reason"),
    ("serve", "ShardError", "shard_error_class"),
    ("tlr", "UpdateError", "update_error_class"),
    ("testing", "FaultKind", "fault_kind_class"),
]


def enum_variants(stripped, enum_name):
    """Variant names of `enum enum_name` in stripped source, or None."""
    m = re.search(r"\benum\s+" + enum_name + r"\b[^{;]*\{", stripped)
    if not m:
        return None
    body, _ = body_span(stripped, m.end() - 1)
    variants = []
    depth = 0
    item = ""
    for c in body + ",":
        if c in "{([":
            depth += 1
        elif c in "})]":
            depth -= 1
        if c == "," and depth == 0:
            vm = re.match(r"\s*(?:#\[[^\]]*\]\s*)*([A-Za-z_]\w*)", item)
            if vm:
                variants.append(vm.group(1))
            item = ""
        else:
            item += c
    return variants


def check_error_observability(src):
    for subdir, enum_name, fn_name in ERROR_MAPPINGS:
        sub_files = {p: s for p, s in src.items()
                     if os.sep + subdir + os.sep in p}
        variants = enum_path = None
        fn_body = fn_path = None
        fn_line = 1
        for path, stripped in sub_files.items():
            if variants is None:
                v = enum_variants(stripped, enum_name)
                if v is not None:
                    variants, enum_path = v, path
            if fn_body is None:
                fm = re.search(r"\bfn\s+" + fn_name + r"\s*\(", stripped)
                if fm:
                    open_idx = stripped.find("{", fm.end())
                    if open_idx != -1:
                        fn_body, _ = body_span(stripped, open_idx)
                        fn_path = path
                        fn_line = stripped.count("\n", 0, fm.start()) + 1
        if variants is None:
            continue  # enum gone: nothing to map
        if fn_body is None:
            warn(enum_path, 1,
                 f"enum {enum_name} has no `fn {fn_name}` mapping its "
                 f"variants to obs counters/events")
            continue
        for v in variants:
            if not re.search(enum_name + r"\s*::\s*" + v + r"\b", fn_body):
                warn(fn_path, fn_line,
                     f"{fn_name}: {enum_name}::{v} is not mapped to a "
                     f"counter or flight-recorder event")


# --------------------------------------------------------- unsafe hygiene


SAFETY_SCAN_LINES = 40
INVENTORY_PATH = os.path.join(ROOT, "tools", "unsafe_inventory.json")

KANI_MOD_RE = re.compile(
    r"#\[cfg\(kani\)\]\s*(?:pub(?:\s*\(crate\))?\s+)?mod\s+\w+\s*\{"
)
KANI_PROOF_RE = re.compile(r"#\[kani::proof\]")
# `// SAFETY: ...` (incl. the doc flavors) or a `/// # Safety` section.
SAFETY_LINE_RE = re.compile(r"//.*(?:\bSAFETY\b|#\s*Safety\b)")
FN_HEADER_LINE_RE = re.compile(r"\bfn\s+\w+")


def safety_text(line):
    """First line of the SAFETY comment, without the comment markers."""
    idx = line.find("//")
    return line[idx:].lstrip("/!").strip()


def find_safety(orig_lines, stripped_lines, ln, kind):
    """Nearest SAFETY comment covering the unsafe site at 1-based line
    `ln`, scanning at most SAFETY_SCAN_LINES upward. For `unsafe {}`
    blocks the scan stops at the enclosing fn header — a comment above
    the header documents the fn, not this block."""
    lo = max(1, ln - SAFETY_SCAN_LINES)
    for k in range(ln, lo - 1, -1):
        if SAFETY_LINE_RE.search(orig_lines[k - 1]):
            return safety_text(orig_lines[k - 1])
        if (
            kind == "unsafe block"
            and k != ln
            and k - 1 < len(stripped_lines)
            and FN_HEADER_LINE_RE.search(stripped_lines[k - 1])
        ):
            break
    return None


def classify_unsafe_sites(path, stripped):
    """(line, kind, item) for every `unsafe` token in stripped source.
    Comments and strings are already blanked, so each hit is code."""
    sites = []
    fn_positions = [
        (m.start(), m.group(1)) for m in re.finditer(r"\bfn\s+(\w+)", stripped)
    ]
    for m in re.finditer(r"\bunsafe\b", stripped):
        j = m.end()
        while j < len(stripped) and stripped[j].isspace():
            j += 1
        rest = stripped[j : j + 400]
        line = stripped.count("\n", 0, m.start()) + 1
        if rest.startswith("fn"):
            fm = re.match(r"fn\s+(\w+)", rest)
            sites.append((line, "unsafe fn", fm.group(1) if fm else "?"))
        elif rest.startswith("impl"):
            end = len(rest)
            for stop in "{;":
                k = rest.find(stop)
                if k != -1:
                    end = min(end, k)
            sites.append((line, "unsafe impl", " ".join(rest[:end].split())))
        elif rest.startswith("trait"):
            tm = re.match(r"trait\s+(\w+)", rest)
            sites.append((line, "unsafe trait", tm.group(1) if tm else "?"))
        elif rest.startswith("extern"):
            sites.append((line, "unsafe extern", "extern block"))
        elif rest.startswith("{"):
            encl = "<file scope>"
            for pos, name in fn_positions:
                if pos < m.start():
                    encl = name
                else:
                    break
            sites.append((line, "unsafe block", f"in fn {encl}"))
        else:
            warn(path, line, "check 10: unclassifiable `unsafe` token")
    return sites


def fn_return_clause(stripped, i):
    """Text between a fn's parameter list and its body/terminator,
    starting the scan at `i` (just past the fn name): the return type
    plus any where clause. None when no parameter list is found."""
    n = len(stripped)
    while i < n and stripped[i].isspace():
        i += 1
    if i < n and stripped[i] == "<":  # generic parameter list
        depth = 0
        while i < n:
            if stripped[i] == "<":
                depth += 1
            elif stripped[i] == ">" and stripped[i - 1] != "-":
                depth -= 1
                if depth == 0:
                    i += 1
                    break
            i += 1
    while i < n and stripped[i] != "(":
        if stripped[i] in "{;":
            return None
        i += 1
    depth = 0
    while i < n:
        if stripped[i] == "(":
            depth += 1
        elif stripped[i] == ")":
            depth -= 1
            if depth == 0:
                i += 1
                break
        i += 1
    j = i
    bracket = 0
    while j < n:
        c = stripped[j]
        if c == "[":
            bracket += 1
        elif c == "]":
            bracket -= 1
        elif c == "{" or (c == ";" and bracket == 0):
            break
        j += 1
    return stripped[i:j]


def check_unsafe_hygiene(texts, stripped_files):
    """Check 10: SAFETY coverage, kani gating, raw-pointer visibility.
    Returns the site inventory for the committed-JSON diff."""
    entries = []
    for path in sorted(stripped_files):
        s = stripped_files[path]
        if "unsafe" not in s and "kani" not in s:
            continue
        orig_lines = texts[path].split("\n")
        stripped_lines = s.split("\n")
        rel = os.path.relpath(path, ROOT).replace(os.sep, "/")
        for line, kind, item in classify_unsafe_sites(path, s):
            safety = find_safety(orig_lines, stripped_lines, line, kind)
            if safety is None:
                warn(path, line,
                     f"check 10: {kind} ({item}) has no `// SAFETY:` comment "
                     f"within {SAFETY_SCAN_LINES} lines")
                safety = ""
            entries.append(
                {"file": rel, "kind": kind, "item": item, "safety": safety}
            )
        kani_spans = []
        for m in KANI_MOD_RE.finditer(s):
            open_idx = s.find("{", m.start())
            if open_idx != -1:
                _, close = body_span(s, open_idx)
                kani_spans.append((m.start(), close))
        for m in KANI_PROOF_RE.finditer(s):
            line = s.count("\n", 0, m.start()) + 1
            if not any(a <= m.start() < b for a, b in kani_spans):
                warn(path, line,
                     "check 10: #[kani::proof] outside a #[cfg(kani)] mod — "
                     "tier-1 rustc would reject it")
        for m in re.finditer(r"\bpub\s+(?:unsafe\s+)?fn\s+(\w+)", s):
            ret = fn_return_clause(s, m.end())
            if ret and re.search(r"\*\s*(?:mut|const)\b", ret):
                line = s.count("\n", 0, m.start()) + 1
                warn(path, line,
                     f"check 10: `pub fn {m.group(1)}` returns a raw pointer; "
                     f"narrow it to pub(crate) or less")
    entries.sort(key=lambda e: (e["file"], e["kind"], e["item"], e["safety"]))
    return entries


def check_inventory(entries, write):
    blob = json.dumps(entries, indent=1, sort_keys=True) + "\n"
    if write:
        with open(INVENTORY_PATH, "w", encoding="utf-8") as f:
            f.write(blob)
        print(f"wrote {len(entries)} unsafe-site entries to "
              f"{os.path.relpath(INVENTORY_PATH, ROOT)}")
        return
    try:
        with open(INVENTORY_PATH, encoding="utf-8") as f:
            committed = json.load(f)
    except (OSError, ValueError):
        committed = None
    if committed != entries:
        warn(INVENTORY_PATH, 1,
             "check 10: unsafe inventory is stale — run `python3 "
             "tools/static_audit.py --write-inventory` and commit the diff")


# --------------------------------------------------------- clippy classes


CLIPPY_PATTERNS = [
    (re.compile(r"\.len\(\)\s*==\s*0\b"), "use .is_empty() (clippy::len_zero)"),
    (re.compile(r"\.len\(\)\s*!=\s*0\b"), "use !.is_empty() (clippy::len_zero)"),
    (re.compile(r"\.len\(\)\s*>\s*0\b"), "use !.is_empty() (clippy::len_zero)"),
    (re.compile(r"==\s*true\b"), "drop `== true` (clippy::bool_comparison)"),
    (re.compile(r"==\s*false\b"), "use `!` (clippy::bool_comparison)"),
]


def check_clippy(path, stripped):
    lines = stripped.split("\n")
    for ln, line in enumerate(lines, 1):
        for pat, msg in CLIPPY_PATTERNS:
            if not pat.search(line):
                continue
            # clippy::len_zero skips `self.len() == 0` inside the
            # `is_empty` definition itself.
            if "self.len()" in line and any(
                "fn is_empty" in lines[k]
                for k in range(max(0, ln - 4), ln)
            ):
                continue
            warn(path, ln, msg)


# ---------------------------------------------------------------- driver


def main():
    rs_files = []
    for base in ("rust", "benches", "examples"):
        for dirpath, _, names in os.walk(os.path.join(ROOT, base)):
            for name in sorted(names):
                if name.endswith(".rs"):
                    rs_files.append(os.path.join(dirpath, name))
    texts = {p: open(p, encoding="utf-8").read() for p in rs_files}
    stripped = {p: strip_code(t, p) for p, t in texts.items()}

    for p in rs_files:
        check_balance(p, stripped[p])
        check_line_lengths(p, texts[p], stripped[p])
        check_generics(p, stripped[p])
        check_clippy(p, stripped[p])

    src = {p: s for p, s in stripped.items()
           if os.sep + os.path.join("rust", "src") + os.sep in p}
    traits = collect_traits(src)
    check_impls(stripped, traits)
    syms = collect_pub_symbols(src)
    check_imports(stripped, syms)
    check_simd_hygiene(stripped)
    check_error_observability(src)
    entries = check_unsafe_hygiene(texts, stripped)
    check_inventory(entries, "--write-inventory" in sys.argv[1:])

    if findings:
        print(f"{len(findings)} finding(s):")
        for f in sorted(set(findings)):
            print("  " + f)
        return 1
    print(f"audit clean: {len(rs_files)} files, {len(traits)} traits checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
